"""Futures-based evaluation services + the overlapped experiment loop:
submit/poll/gather/drain semantics, out-of-order tells, failure handling
(failed EvalResult -> infeasible DB row, never a crashed run), EvalDB
writer-lock integrity, history caps, and the deprecated-wrapper warnings.

Every test runs under a 120 s watchdog (POSIX SIGALRM): a deadlocked
``gather``/``drain`` fails fast instead of hanging the suite/CI workflow.
"""

import json
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.controller import Controller, EvalDB, EvalRecord
from repro.core.service import (CallableServiceAdapter, EvalRequest,
                                EvaluationService, FidelityRouter,
                                ImmediateEvaluationService,
                                WorkerPoolEvaluationService, as_service)
from repro.core.space import Knob, Space
from repro.core.strategy import BOConfig, BOStrategy, RandomStrategy

WATCHDOG_S = 120


@pytest.fixture(autouse=True)
def _watchdog():
    """Deadlock guard for the whole module: a stuck gather/drain raises
    instead of hanging the workflow (no-op where SIGALRM is missing)."""
    if not hasattr(signal, "SIGALRM"):
        yield
        return

    def _fire(signum, frame):
        raise TimeoutError(f"async-service test exceeded {WATCHDOG_S}s "
                           "(deadlocked gather/poll?)")

    old = signal.signal(signal.SIGALRM, _fire)
    signal.alarm(WATCHDOG_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _space():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0)))


def _f(c):
    return (c["x"] - 0.3) ** 2 + (c["y"] - 0.7) ** 2


# ---------------------------------------------------------------------------
# service protocol semantics
# ---------------------------------------------------------------------------

class TestServiceProtocol:
    def test_immediate_submit_poll_gather_drain(self):
        svc = CallableServiceAdapter(_f)
        cfgs = [{"x": 0.1 * i, "y": 0.5} for i in range(4)]
        tickets = svc.submit([EvalRequest(c, tag="t") for c in cfgs])
        assert [t.uid for t in tickets] == [0, 1, 2, 3]
        assert svc.in_flight == 0 and svc.ready == 4
        res = svc.gather(tickets[1:3])            # specific, ticket order
        assert [r.ticket.uid for r in res] == [1, 2]
        assert all(r.ok and r.status == "ok" for r in res)
        assert res[0].value == pytest.approx(_f(cfgs[1]))
        rest = svc.poll()                         # the unclaimed remainder
        assert sorted(r.ticket.uid for r in rest) == [0, 3]
        assert svc.drain() == []

    def test_gather_unknown_ticket_raises(self):
        svc = CallableServiceAdapter(_f)
        (t,) = svc.submit([EvalRequest({"x": 0.5, "y": 0.5})])
        svc.gather([t])
        with pytest.raises(KeyError):
            svc.gather([t])                       # already claimed

    def test_result_carries_request_fields(self):
        svc = CallableServiceAdapter(_f)
        req = EvalRequest({"x": 0.2, "y": 0.9}, fidelity="screen",
                          workload="yi-6b:train_4k", tag="rank", seed=5)
        (r,) = svc.gather(svc.submit([req]))
        assert r.request is req and r.config == req.config
        assert (r.request.fidelity, r.request.workload,
                r.request.tag, r.request.seed) == ("screen",
                                                   "yi-6b:train_4k",
                                                   "rank", 5)

    def test_failure_is_a_result_not_an_exception(self):
        def boom(c):
            raise ValueError("no such config")
        svc = CallableServiceAdapter(boom)
        (r,) = svc.gather(svc.submit([EvalRequest({"x": 1, "y": 1})]))
        assert not r.ok and r.status == "failed" and not r.feasible
        assert "no such config" in r.error and np.isnan(r.value)

    def test_worker_pool_streams_out_of_order(self):
        def slow(c):
            time.sleep(c["x"])                   # latency keyed by config
            return c["x"]
        with WorkerPoolEvaluationService(slow, max_workers=3) as svc:
            reqs = [EvalRequest({"x": d, "y": 0}) for d in (0.15, 0.02, 0.08)]
            tickets = svc.submit(reqs)
            res = svc.drain()
            assert [r.ticket.uid for r in res] != [t.uid for t in tickets]
            assert sorted(r.value for r in res) == [0.02, 0.08, 0.15]
            # gather-after-drain on nothing in flight is an error
            with pytest.raises(KeyError):
                svc.gather(tickets)

    def test_worker_pool_failure_streams_back(self):
        def flaky(c):
            if c["x"] > 0.5:
                raise RuntimeError("OOM")
            return c["x"]
        with WorkerPoolEvaluationService(flaky, max_workers=2) as svc:
            res = svc.gather(svc.submit(
                [EvalRequest({"x": v}) for v in (0.1, 0.9, 0.2)]))
            assert [r.ok for r in res] == [True, False, True]
            assert "OOM" in res[1].error

    def test_fidelity_dict_routes_and_unknown_fails(self):
        svc = ImmediateEvaluationService({"cheap": lambda c: 1.0,
                                          "costly": lambda c: 2.0})
        assert svc.fidelities == ("cheap", "costly")
        res = svc.gather(svc.submit([
            EvalRequest({}, fidelity="costly"),
            EvalRequest({}, fidelity="cheap"),
            EvalRequest({}, fidelity="nonsense")]))
        assert [r.value for r in res[:2]] == [2.0, 1.0]
        assert not res[2].ok and "nonsense" in res[2].error

    def test_fidelity_router_composes_services(self):
        pool = WorkerPoolEvaluationService(lambda c: c["x"] * 10,
                                           max_workers=2)
        router = FidelityRouter({"screen": CallableServiceAdapter(_f),
                                 "promote": pool})
        try:
            reqs = [EvalRequest({"x": 0.3, "y": 0.7}, fidelity="screen"),
                    EvalRequest({"x": 0.4, "y": 0.0}, fidelity="promote")]
            res = router.gather(router.submit(reqs))
            assert res[0].value == pytest.approx(0.0)
            assert res[1].value == pytest.approx(4.0)
            # router tickets, not the routes' internal ones
            assert [r.request.fidelity for r in res] == ["screen", "promote"]
            assert router.drain() == []
        finally:
            router.close()
            pool.close()

    def test_fidelity_router_unrouted_fidelity_fails_not_deadlocks(self):
        """A request with no route must come back as a failed result —
        an orphaned ticket would deadlock every later gather/drain."""
        router = FidelityRouter({"screen": CallableServiceAdapter(_f)})
        try:
            res = router.gather(router.submit([
                EvalRequest({"x": 0.3, "y": 0.7}),          # default "test"
                EvalRequest({"x": 0.3, "y": 0.7}, fidelity="screen")]))
            assert not res[0].ok and "no route" in res[0].error
            assert res[1].ok
            assert router.drain() == []                     # nothing stuck
        finally:
            router.close()

    def test_as_service_normalization(self):
        svc = CallableServiceAdapter(_f)
        assert as_service(svc) is svc
        assert isinstance(as_service(_f), CallableServiceAdapter)
        assert isinstance(as_service(_f), EvaluationService)

        class Poolish:
            service_kind = "pool"
            max_workers = 2

            def __call__(self, c):
                return 1.0

        assert isinstance(as_service(Poolish()), WorkerPoolEvaluationService)
        with pytest.raises(TypeError):
            as_service(object())


# ---------------------------------------------------------------------------
# run_async: equivalence, out-of-order tells, failures
# ---------------------------------------------------------------------------

def _bo(seed=7):
    return BOStrategy(_space(), BOConfig(n_init=4, n_iter=8, batch_size=4,
                                         n_candidates=32, fit_steps=10,
                                         seed=seed))


class ShufflingService(ImmediateEvaluationService):
    """Immediate completion but *shuffled* claim order: every poll hands
    completions back in a seeded random order, modelling workers that
    finish out of order."""

    def __init__(self, backend, seed=0):
        super().__init__(backend)
        self._rng = np.random.default_rng(seed)

    def poll(self, timeout=0.0):
        with self._cv:
            self._rng.shuffle(self._order)
        return super().poll(timeout)


class TestRunAsync:
    def test_matches_run_exactly_on_immediate_service(self):
        t_sync = Controller(_f, EvalDB()).run(_bo())
        t_async = Controller(_f, EvalDB()).run_async(_bo())
        assert t_sync.configs == t_async.configs
        assert t_sync.values == t_async.values

    def test_matches_run_exactly_with_budget_cap(self):
        """A driver-level budget must not distort the strategy's batch
        width: ask(None) stays ask(None) in both loops, the final round
        is truncated identically."""
        from repro.core.strategy import AnnealingStrategy
        for mk in (_bo, lambda: AnnealingStrategy(_space(), 30, seed=2)):
            t_sync = Controller(_f, EvalDB()).run(mk(), budget=9)
            t_async = Controller(_f, EvalDB()).run_async(mk(), budget=9)
            assert t_sync.configs == t_async.configs
            assert t_sync.values == t_async.values

    def test_protocol_only_service_terminates(self):
        """run_async must need nothing beyond submit/poll/gather/drain —
        a minimal protocol-only service (no in_flight/ready attributes)
        still drives to completion."""

        class Minimal:
            def __init__(self):
                self._done = []
                self._uid = 0

            def submit(self, reqs):
                from repro.core.service import EvalResult, EvalTicket
                ts = []
                for r in reqs:
                    ts.append(EvalTicket(self._uid, r))
                    self._uid += 1
                self._done += [EvalResult(t, _f(t.request.config),
                                          wall_s=0.0) for t in ts]
                return ts

            def poll(self, timeout=0.0):
                out, self._done = self._done, []
                return out

            def gather(self, tickets):
                return self.poll()

            def drain(self):
                return self.poll()

        trace = Controller(Minimal(), EvalDB()).run_async(
            RandomStrategy(_space(), 12, seed=0, batch_size=4))
        assert len(trace.values) == 12

    def test_shuffled_completion_order_reproduces_best(self):
        """Out-of-order tells: the strategy sees the same observations in
        a different order, so the best found matches the synchronous loop
        within the usual noise tolerance."""
        t_sync = Controller(_f, EvalDB()).run(_bo())
        svc = ShufflingService(_f, seed=11)
        t_shuf = Controller(svc, EvalDB()).run_async(_bo())
        assert sorted(t_shuf.values) != t_shuf.values   # genuinely shuffled
        assert len(t_shuf.values) == len(t_sync.values)
        b_sync, b_shuf = t_sync.best[1], t_shuf.best[1]
        assert b_shuf <= b_sync * 1.05 + 1e-9

    def test_worker_pool_out_of_order_full_budget(self):
        def jittered(c):
            time.sleep(0.001 + 0.01 * c["x"])
            return _f(c)
        with WorkerPoolEvaluationService(jittered, max_workers=4) as svc:
            db = EvalDB()
            trace = Controller(svc, db).run_async(
                RandomStrategy(_space(), 30, seed=1, batch_size=10),
                max_in_flight=8)
        assert len(trace.values) == 30 and len(db) == 30
        assert {r.status for r in db.records} == {"ok"}

    def test_failed_worker_yields_infeasible_row_not_crash(self, tmp_path):
        def flaky(c):
            if c["x"] > 0.8:
                raise ValueError("OOM: config does not fit")
            return _f(c)
        db = EvalDB(str(tmp_path / "evals.jsonl"))
        ctrl = Controller(flaky, db, tag="search")
        trace = ctrl.run_async(RandomStrategy(_space(), 25, seed=0))
        assert len(trace.values) == 25                 # run completed
        bad = [r for r in db.records if not r.ok]
        assert bad and all(r.status == "failed" for r in bad)
        # penalties are strictly worse than every successful value
        ok_vals = [r.value for r in db.records if r.ok]
        assert min(r.value for r in bad) > max(ok_vals)
        # failed rows are excluded from training pairs by default
        cfgs, vals = db.pairs("search")
        assert len(cfgs) == 25 - len(bad)
        _, all_vals = db.pairs("search", include_failed=True)
        assert len(all_vals) == 25
        # and the reloaded DB agrees
        db2 = EvalDB(str(tmp_path / "evals.jsonl"))
        assert sum(not r.ok for r in db2.records) == len(bad)

    def test_failures_before_any_success_are_priced_off_real_scale(self):
        """A failure wave arriving before the first success is held back
        and priced once real values fix the scale — a guessed absolute
        penalty (1e6) could accidentally beat genuine values (say ~1e8)."""
        calls = {"n": 0}

        def hot_start(c):
            calls["n"] += 1
            if calls["n"] <= 3:
                raise ValueError("cluster warming up")
            return 1e8 + 1e6 * c["x"]                  # huge objective
        db = EvalDB()
        trace = Controller(hot_start, db).run_async(
            RandomStrategy(_space(), 12, seed=0, batch_size=3))
        assert len(trace.values) == 12
        bad = [r.value for r in db.records if not r.ok]
        ok = [r.value for r in db.records if r.ok]
        assert len(bad) == 3
        assert min(bad) > max(ok)          # never better than a real value

    def test_all_failures_run_terminates_at_fallback(self):
        def always(c):
            raise ValueError("nothing works")
        db = EvalDB()
        trace = Controller(always, db).run_async(
            RandomStrategy(_space(), 6, seed=0, batch_size=3))
        assert len(trace.values) == 6
        assert all(v == 1e6 for v in trace.values)
        assert all(not r.ok for r in db.records)

    def test_sync_failure_chains_and_writes_strict_json(self, tmp_path):
        def boom(c):
            raise ValueError("bad knob combo")
        p = tmp_path / "evals.jsonl"
        db = EvalDB(str(p))
        with pytest.raises(RuntimeError, match="bad knob") as ei:
            Controller(boom, db, tag="t").evaluate_batch([{"x": 1}])
        assert isinstance(ei.value.__cause__, ValueError)   # chain kept
        # the failed row was recorded, as strict JSON (value null, no NaN)
        (line,) = p.read_text().splitlines()
        d = json.loads(line, parse_constant=lambda s: pytest.fail(
            f"non-strict JSON token {s!r} in EvalDB line"))
        assert d["value"] is None and d["status"] == "failed"
        (rec,) = EvalDB(str(p)).records
        assert np.isnan(rec.value) and not rec.ok

    def test_default_fidelity_not_serialized(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        db = EvalDB(str(p))
        ctrl = Controller(_f, db, tag="t")
        ctrl.evaluate_batch([{"x": 0.5, "y": 0.5}])
        ctrl.evaluate_batch([{"x": 0.5, "y": 0.5}], fidelity="screen")
        l1, l2 = p.read_text().splitlines()
        assert "fidelity" not in json.loads(l1)     # default stays lean
        assert json.loads(l2)["fidelity"] == "screen"

    def test_async_ranking_excludes_failed_samples(self):
        from repro.core import ranking

        def flaky(c):
            if c["x"] > 0.85:
                raise ValueError("boom")
            return _f(c)
        db = EvalDB()
        rk = ranking.rank_with_controller(
            _space(), Controller(flaky, db, tag="rank"), n_samples=40,
            seed=0, async_eval=True)
        n_failed = sum(not r.ok for r in db.records)
        assert n_failed > 0                        # scenario is exercised
        assert len(rk.samples) == 40 - n_failed
        assert max(rk.values) < 1e5                # no penalty outliers

    def test_failure_value_override_and_budget_cap(self):
        def flaky(c):
            if c["x"] > 0.9:
                raise ValueError("boom")
            return _f(c)
        ctrl = Controller(flaky, EvalDB())
        trace = ctrl.run_async(RandomStrategy(_space(), 50, seed=0,
                                              batch_size=8),
                               budget=20, failure_value=123.0)
        assert len(trace.values) == 20
        assert all(v == 123.0 for c, v in zip(trace.configs, trace.values)
                   if c["x"] > 0.9)

    def test_min_ask_coalesces_waves(self):
        def slow(c):
            time.sleep(0.002)
            return _f(c)
        asks = []
        strat = RandomStrategy(_space(), 24, seed=2)
        orig = strat.ask
        strat.ask = lambda n=None: [a for a in orig(n) if asks.append(n) or True]
        with WorkerPoolEvaluationService(slow, max_workers=4) as svc:
            Controller(svc, EvalDB()).run_async(strat, max_in_flight=8,
                                                min_ask=4)
        # after the initial fill every ask had at least min_ask of room
        assert all(n is None or n >= 4 for n in asks)

    def test_run_async_applies_prepare_and_workload(self):
        sub = _space().subset(["x"])
        full = _space().completer()
        db = EvalDB()
        ctrl = Controller(_f, db, tag="s",
                          workload="cell:a").with_prepare(full)
        ctrl.run_async(RandomStrategy(sub, 6, seed=0))
        assert all(set(r.config) == {"x", "y"} for r in db.records)
        assert all(r.workload == "cell:a" for r in db.records)
        assert all(r.fidelity == "test" for r in db.records)


# ---------------------------------------------------------------------------
# default in-flight cap: bounded staleness out of the box (PR 6)
# ---------------------------------------------------------------------------

class TestInFlightAutoCap:
    """``max_in_flight=None`` caps pending work at 4x the strategy's
    batch width instead of letting a slow service absorb the whole
    remaining budget against one stale posterior; ``max_in_flight <= 0``
    restores the old unbounded behavior; and the automatic cap only
    *gates* asks — it never shapes their width — so immediate-service
    traces are byte-identical with the gate on or off."""

    def _peak_concurrency(self, max_in_flight):
        state = {"cur": 0, "peak": 0}
        lock = threading.Lock()

        def slow(c):
            with lock:
                state["cur"] += 1
                state["peak"] = max(state["peak"], state["cur"])
            time.sleep(0.05)
            with lock:
                state["cur"] -= 1
            return _f(c)

        strat = RandomStrategy(_space(), 48, seed=3, batch_size=4)
        with WorkerPoolEvaluationService(slow, max_workers=48) as svc:
            Controller(svc, EvalDB()).run_async(
                strat, max_in_flight=max_in_flight)
        assert len(strat.trace.values) == 48
        return state["peak"]

    def test_default_caps_at_four_batch_widths(self):
        # batch_size=4 -> auto cap 16: with 48 eager workers the pool
        # can only ever hold what the driver lets in flight
        assert self._peak_concurrency(None) <= 16

    def test_zero_restores_unbounded(self):
        assert self._peak_concurrency(0) > 16

    def test_gate_never_changes_immediate_trace(self):
        def run(max_in_flight):
            cfg = BOConfig(n_init=4, n_iter=8, batch_size=2,
                           n_candidates=32, fit_steps=10, seed=5)
            strat = BOStrategy(_space(), cfg)
            svc = ImmediateEvaluationService(_f)
            Controller(svc, EvalDB()).run_async(
                strat, max_in_flight=max_in_flight)
            return strat.trace

        capped = run(None)
        unbounded = run(0)
        assert capped.configs == unbounded.configs
        assert np.allclose(capped.values, unbounded.values)


class TestSapphireAsync:
    def test_async_pipeline_reproduces_sync_best(self):
        """Acceptance: the async experiment loop over the immediate
        analytic service reproduces the synchronous pipeline at equal
        budget and seed — here exactly (same noise stream, same trace),
        which is stronger than the within-noise requirement."""
        from repro.core.tuner import Sapphire

        def make(async_eval):
            return Sapphire(arch="yi-6b", shape="train_4k", top_k=8,
                            n_rank_samples=40, batch_size=4,
                            bo_config=BOConfig(n_init=4, n_iter=8,
                                               batch_size=4, warm_start=True,
                                               n_candidates=64, fit_steps=20,
                                               seed=5),
                            seed=5, async_eval=async_eval)

        sync_res = make(False).tune()
        async_res = make(True).tune()
        assert async_res.n_evaluations == sync_res.n_evaluations == 40 + 12
        assert async_res.best_value == pytest.approx(sync_res.best_value)
        assert async_res.trace.configs == sync_res.trace.configs


# ---------------------------------------------------------------------------
# fidelity as a request field: successive halving without a second object
# ---------------------------------------------------------------------------

class TestFidelityField:
    def test_successive_halving_high_none_routes_by_fidelity(self):
        svc = ImmediateEvaluationService(
            {"screen": lambda c: _f(c) + 0.07, "promote": _f})
        db = EvalDB()
        ctrl = Controller(svc, db)
        best_c, best_v, sched = ctrl.run_successive_halving(
            RandomStrategy(_space(), budget=None, seed=0),
            rounds=3, screen=8, promote=2)
        assert [(s["screened"], s["promoted"]) for s in sched] == [(8, 2)] * 3
        fids = [r.fidelity for r in db.records]
        assert fids.count("screen") == 24 and fids.count("promote") == 6
        assert best_v == pytest.approx(_f(best_c))

    def test_derived_controllers_share_one_service(self):
        """with_tag/with_prepare/with_workload derivatives must resolve
        to THIS controller's service — one worker pool total, not one
        per tag (the Sapphire flow derives before ever evaluating)."""

        class Pooled:
            service_kind = "pool"
            max_workers = 2

            def __call__(self, c):
                return 1.0

        base = Controller(Pooled(), EvalDB())
        a = base.with_tag("rank")
        b = base.with_tag("bo").with_prepare(lambda c: c).with_workload("w")
        assert a.service is b.service is base.service
        assert isinstance(base.service, WorkerPoolEvaluationService)

    def test_sync_evaluate_batch_stamps_fidelity(self):
        db = EvalDB()
        ctrl = Controller(_f, db, tag="t")
        ctrl.evaluate_batch([{"x": 0.5, "y": 0.5}], fidelity="screen")
        assert db.records[0].fidelity == "screen"


# ---------------------------------------------------------------------------
# EvalDB: concurrent appends cannot tear lines
# ---------------------------------------------------------------------------

class TestEvalDBConcurrency:
    def test_concurrent_append_batches_roundtrip(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        db = EvalDB(str(p))
        n_threads, per_thread = 8, 25

        def writer(tid):
            for i in range(per_thread):
                db.append(EvalRecord({"tid": tid, "i": i}, float(i), 0.0,
                                     f"t{tid}", "w", "test"))

        threads = [threading.Thread(target=writer, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(db) == n_threads * per_thread
        # every line parses and the full multiset of records round-trips
        lines = p.read_text().splitlines()
        assert len(lines) == n_threads * per_thread
        parsed = [json.loads(ln) for ln in lines]
        for tid in range(n_threads):
            mine = sorted(d["config"]["i"] for d in parsed
                          if d["config"]["tid"] == tid)
            assert mine == list(range(per_thread))
        db2 = EvalDB(str(p))
        assert len(db2) == len(db)

    def test_legacy_lines_reload_with_defaults(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        p.write_text('{"config": {"x": 1}, "value": 2.0, "wall_s": 0.1, '
                     '"tag": "bo"}\n')
        (rec,) = EvalDB(str(p)).records
        assert (rec.workload, rec.fidelity, rec.status) == ("", "", "ok")
        assert rec.ok


# ---------------------------------------------------------------------------
# bounded histories + compiled-evaluator thread safety
# ---------------------------------------------------------------------------

class TestBoundedHistory:
    def test_analytic_history_cap(self, tmp_path):
        from repro.configs import get_config
        from repro.core.costmodel import SINGLE_POD
        from repro.core.evaluators import AnalyticEvaluator
        from repro.core.knobs import clean_space
        from repro.core.sampling import latin_hypercube
        from repro.models.config import SHAPES_BY_NAME
        model_cfg = get_config("yi-6b")
        cell = SHAPES_BY_NAME["train_4k"]
        space, _, _ = clean_space(model_cfg, cell, SINGLE_POD)
        cfgs = latin_hypercube(space, 12, seed=0)

        capped = AnalyticEvaluator(model_cfg, cell, SINGLE_POD, seed=7,
                                   history_cap=5)
        free = AnalyticEvaluator(model_cfg, cell, SINGLE_POD, seed=7)
        v_cap = capped.evaluate_batch(cfgs)
        v_free = free.evaluate_batch(cfgs)
        assert np.allclose(v_cap, v_free)          # cap never changes values
        assert len(capped.history) == 5 and len(free.history) == 12
        # ring semantics: the newest records survive
        assert capped.history == free.history[-5:]
        assert capped.calls == 12

    def test_compiled_thread_safe_and_capped(self):
        from repro.core.evaluators import CompiledEvaluator
        ev = CompiledEvaluator.__new__(CompiledEvaluator)
        ev.multi_pod = False
        ev.max_workers = 4
        ev.history_cap = 8
        ev.calls = 0
        ev.history = []
        ev._cache = {}
        ev._lock = threading.Lock()
        ev._compile = lambda knobs: 0.001 * knobs["i"]   # stub the dry-run

        def work(base):
            for i in range(25):
                ev({"i": base * 25 + i})

        threads = [threading.Thread(target=work, args=(b,)) for b in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert ev.calls == 100 and len(ev._cache) == 100
        assert len(ev.history) == 8                # capped
        # cache hits are lock-protected and stable
        assert ev({"i": 42}) == pytest.approx(0.042)
        assert ev.calls == 100


# ---------------------------------------------------------------------------
# one batch-or-loop shim + deprecation warnings
# ---------------------------------------------------------------------------

class TestDeprecatedWrappers:
    def test_evaluate_many_raises_on_failure(self):
        from repro.core.evaluators import evaluate_many

        def boom(c):
            raise ValueError("bad config")
        with pytest.raises(RuntimeError, match="bad config"):
            evaluate_many(boom, [{"x": 1}])

    def test_bo_minimize_warns(self):
        cfg = BOConfig(n_init=2, n_iter=2, n_candidates=16, fit_steps=5)
        from repro.core import bo
        with pytest.warns(DeprecationWarning, match="Controller"):
            bo.minimize(_f, _space(), cfg)

    def test_optimizers_warn(self):
        from repro.core import optimizers as opt
        with pytest.warns(DeprecationWarning, match="make_strategy"):
            opt.random_search(_f, _space(), 4, seed=0)
        with pytest.warns(DeprecationWarning, match="make_strategy"):
            opt.simulated_annealing(_f, _space(), 4)
        with pytest.warns(DeprecationWarning, match="make_strategy"):
            opt.genetic_algorithm(_f, _space(), 10)


# ---------------------------------------------------------------------------
# mid-flight worker death + the hung-probe watchdog
# ---------------------------------------------------------------------------

class TestWorkerDeathAndWatchdog:
    def test_poll_timeout_returns_landed_results_around_dead_worker(self):
        """poll(timeout) must hand back what HAS completed and come home
        on time while one worker is wedged mid-flight."""
        gate = threading.Event()

        def sometimes_dead(c):
            if c["x"] > 0.5:
                gate.wait(10.0)             # wedged until released
                raise RuntimeError("worker died mid-probe")
            return c["x"]

        svc = WorkerPoolEvaluationService(sometimes_dead, max_workers=3)
        try:
            svc.submit([EvalRequest({"x": v}) for v in (0.1, 0.9, 0.2)])
            landed: list = []
            t0 = time.monotonic()
            while len(landed) < 2 and time.monotonic() - t0 < 5.0:
                landed += svc.poll(timeout=0.1)
            assert sorted(r.value for r in landed) == [0.1, 0.2]
            assert svc.in_flight == 1       # the dead one is still out
            t0 = time.monotonic()
            assert svc.poll(timeout=0.1) == []
            assert time.monotonic() - t0 < 2.0
            gate.set()                      # let it die
            (r,) = svc.drain()
            assert not r.ok and "died" in r.error
        finally:
            gate.set()
            svc.close()

    def test_drain_unwedged_by_deadline_watchdog(self):
        """A probe that never returns completes as failed-transient at
        deadline_s instead of wedging drain forever."""
        gate = threading.Event()

        def hung(c):
            gate.wait(10.0)
            return 0.0

        svc = WorkerPoolEvaluationService(hung, max_workers=2,
                                          deadline_s=0.2)
        try:
            svc.submit([EvalRequest({"x": 0.5, "y": 0.5}, seed=1)])
            t0 = time.monotonic()
            (r,) = svc.drain()
            assert time.monotonic() - t0 < 5.0
            assert not r.ok and r.error_kind == "transient"
            assert "deadline" in r.error and svc.timed_out == 1
        finally:
            gate.set()
            svc.close()

    def test_late_completion_after_watchdog_is_dropped(self):
        """The real result landing after its watchdog already settled
        the ticket must vanish (exactly-once), not resurface in poll."""
        def slow(c):
            time.sleep(0.4)
            return 7.0

        svc = WorkerPoolEvaluationService(slow, max_workers=1,
                                          deadline_s=0.1)
        try:
            (t,) = svc.submit([EvalRequest({"x": 0.5, "y": 0.5})])
            (r,) = svc.gather([t])
            assert not r.ok and r.error_kind == "transient"
            time.sleep(0.5)                 # worker finishes late
            assert svc.poll() == [] and svc.ready == 0
        finally:
            svc.close()

    def test_fast_workers_never_hit_the_deadline(self):
        svc = WorkerPoolEvaluationService(_f, max_workers=2, deadline_s=5.0)
        try:
            res = svc.gather(svc.submit(
                [EvalRequest({"x": 0.1 * i, "y": 0.5}) for i in range(6)]))
            assert all(r.ok for r in res) and svc.timed_out == 0
            assert not svc._watchdogs       # every timer cancelled
        finally:
            svc.close()

    def test_as_service_forwards_deadline(self):
        class Poolish:
            service_kind = "pool"
            max_workers = 2
            deadline_s = 1.5

            def __call__(self, c):
                return 1.0

        svc = as_service(Poolish())
        assert isinstance(svc, WorkerPoolEvaluationService)
        assert svc.deadline_s == 1.5
        svc.close()

    def test_run_async_survives_mid_flight_death(self, tmp_path):
        """The overlapped loop keeps going when workers die mid-run:
        watchdogged probes become infeasible rows, the budget is spent
        exactly once, and the run terminates."""
        def flaky(c):
            if c["x"] > 0.7:
                time.sleep(5.0)             # effectively dead
            return c["x"]

        svc = WorkerPoolEvaluationService(flaky, max_workers=2,
                                          deadline_s=0.3)
        try:
            db = EvalDB(str(tmp_path / "deaths.jsonl"))
            ctrl = Controller(svc, db, tag="async", seed=3)
            strat = RandomStrategy(_space(), budget=12, batch_size=4,
                                   seed=3)
            trace = ctrl.run_async(strat, batch_size=4)
            assert len(db) == 12 and len(trace.values) == 12
            assert any(not r.ok for r in db.records)
            assert any(r.ok for r in db.records)
        finally:
            svc.close()

"""Sharded q-EI candidate scoring (gp.select_batch_sharded).

Guards the PR-6 tentpole contracts:

* the pool mesh helpers (``repro.parallel.sharding``) — deterministic
  device order (part of the pick-reproducibility contract) and the
  spare-device rule for background refits;
* ``gp.select_batch_sharded`` picks **bit-identically** to
  ``gp.select_batch`` on the same pool — on a 1-device mesh through both
  entry points (``shard_map`` and the ``pmap`` CPU fallback), across
  fantasy x acquisition, odd pool sizes (exercising the pad-to-multiple
  rows, pre-marked taken) and the Pallas cross-Gram;
* the same identity under *real* multi-device partitioning — a
  subprocess forces 2 CPU devices via ``XLA_FLAGS`` (it must be set
  before jax imports, hence the re-exec) and checks both entry points;
* ``BOConfig.shard_candidates`` never changes a trace: on a 1-device
  host the gate falls back to plain ``select_batch``, and the strategy's
  picks match the gate-off run config for config.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import gp
from repro.core.space import Knob, Space
from repro.core.strategy import BOConfig, BOStrategy
from repro.parallel.sharding import (POOL_AXIS, pool_devices, pool_mesh,
                                     spare_device)

REPO = Path(__file__).resolve().parent.parent


def _problem(n=26, d=3, q=3, seed=0, steps=30):
    rng = np.random.default_rng(seed)
    x = rng.random((n, d))
    y = (np.sin(3 * x[:, 0]) + (x[:, 1] - 0.4) ** 2
         + 0.1 * rng.normal(size=n))
    st = gp.fit(x, y, steps=steps, pad_to=gp._bucket(n + q))
    y_raw = np.zeros(int(st.x.shape[0]), np.float32)
    y_raw[:n] = y
    return st, y_raw, n, float(np.min(y))


class TestPoolMesh:
    def test_pool_devices_deterministic_prefix(self):
        devs = pool_devices()
        assert devs == tuple(jax.devices())
        assert pool_devices(1) == (jax.devices()[0],)
        assert pool_devices(99) == devs          # clamped to the host

    def test_pool_mesh_axis(self):
        mesh = pool_mesh(1)
        assert mesh.axis_names == (POOL_AXIS,)
        assert mesh.shape[POOL_AXIS] == 1

    def test_spare_device_single_host(self):
        # tests run on the host's single device: background work shares it
        if len(jax.devices()) == 1:
            assert spare_device() is None
        else:
            d = spare_device()
            assert d is not None and d != jax.devices()[0]


class TestSingleDeviceIdentity:
    """nd=1 sharded path == select_batch, both entry points.  The mesh
    machinery (padding, collective argmax, masked psum gathers) is fully
    exercised; only the cross-device traffic is degenerate."""

    @pytest.mark.parametrize("fantasy", ["liar", "believer"])
    @pytest.mark.parametrize("acq", ["ei", "ucb"])
    def test_matches_select_batch(self, fantasy, acq):
        st, y_raw, n, best_y = _problem(seed=1)
        cand = np.random.default_rng(2).random((37, 3)).astype(np.float32)
        base = np.asarray(gp.select_batch(
            st, cand, y_raw, n, best_y, 3, fantasy=fantasy,
            acquisition=acq))
        for use_sm in (False, True):
            picks = np.asarray(gp.select_batch_sharded(
                st, cand, y_raw, n, best_y, 3, fantasy=fantasy,
                acquisition=acq, use_shard_map=use_sm))
            assert np.array_equal(base, picks), \
                f"{fantasy}/{acq} use_shard_map={use_sm}"

    def test_q1_and_even_pool(self):
        st, y_raw, n, best_y = _problem(n=20, q=1, seed=3)
        cand = np.random.default_rng(4).random((64, 3)).astype(np.float32)
        base = np.asarray(gp.select_batch(st, cand, y_raw, n, best_y, 1))
        picks = np.asarray(gp.select_batch_sharded(
            st, cand, y_raw, n, best_y, 1))
        assert np.array_equal(base, picks)

    def test_pad_rows_never_picked(self):
        """Explicit 1-device tuple + odd pool: the pad row (unit-cube
        midpoint, often a genuinely good candidate) is pre-marked taken
        and must never appear in the picks."""
        st, y_raw, n, best_y = _problem(seed=5)
        cand = np.random.default_rng(6).random((41, 3)).astype(np.float32)
        picks = np.asarray(gp.select_batch_sharded(
            st, cand, y_raw, n, best_y, 4,
            devices=(jax.devices()[0],)))
        assert np.all(picks < 41)
        base = np.asarray(gp.select_batch(st, cand, y_raw, n, best_y, 4))
        assert np.array_equal(base, picks)

    def test_use_pallas_cross_gram(self):
        st, y_raw, n, best_y = _problem(seed=7)
        cand = np.random.default_rng(8).random((33, 3)).astype(np.float32)
        base = np.asarray(gp.select_batch(
            st, cand, y_raw, n, best_y, 3, use_pallas=True))
        picks = np.asarray(gp.select_batch_sharded(
            st, cand, y_raw, n, best_y, 3, use_pallas=True))
        assert np.array_equal(base, picks)


_TWO_DEVICE_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.core import gp

assert jax.local_device_count() == 2, jax.devices()
n, d, q = 18, 3, 3
rng = np.random.default_rng(0)
x = rng.random((n, d))
y = np.sin(3 * x[:, 0]) + (x[:, 1] - 0.4) ** 2 + 0.1 * rng.normal(size=n)
st = gp.fit(x, y, steps=15, pad_to=gp._bucket(n + q))
y_raw = np.zeros(int(st.x.shape[0]), np.float32)
y_raw[:n] = y
best_y = float(np.min(y))
cand = rng.random((41, d)).astype(np.float32)   # odd: one pad row/shard
base = np.asarray(gp.select_batch(st, cand, y_raw, n, best_y, q))
for use_sm in (False, True):
    picks = np.asarray(gp.select_batch_sharded(
        st, cand, y_raw, n, best_y, q, use_shard_map=use_sm))
    assert np.array_equal(base, picks), (use_sm, base, picks)
print("IDENTICAL", base.tolist())
"""


class TestForcedTwoDevices:
    def test_picks_identical_across_two_devices(self):
        """Both mesh entry points partition the pool over 2 forced CPU
        devices and still reproduce select_batch bit for bit."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (str(REPO / "src"),
                        env.get("PYTHONPATH", "")) if p)
        out = subprocess.run(
            [sys.executable, "-c", _TWO_DEVICE_SCRIPT], env=env,
            capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
        assert "IDENTICAL" in out.stdout


class TestStrategyGate:
    def _space(self, d=3):
        return Space(tuple(Knob(f"x{i}", "float", 0.5, lo=0.0, hi=1.0)
                           for i in range(d)))

    def _run(self, shard_candidates):
        cfg = BOConfig(n_init=5, n_iter=6, batch_size=2, n_candidates=48,
                       n_local=16, fit_steps=15, seed=11,
                       shard_candidates=shard_candidates)
        strat = BOStrategy(self._space(), cfg)
        rng = np.random.default_rng(12)
        while not strat.finished:
            probes = strat.ask()
            if not probes:
                break
            vals = [float(np.sum((np.array([c[f"x{i}"] for i in range(3)])
                                  - 0.3) ** 2)
                          + 0.01 * rng.standard_normal())
                    for c in probes]
            # deterministic objective noise per config order: both runs
            # see identical values because picks must be identical
            strat.tell(probes, vals)
        return strat.trace

    def test_gate_never_changes_trace(self):
        """shard_candidates=True on this host (single device: fallback;
        multi-device: bit-identical sharded picks) reproduces the
        gate-off trace config for config."""
        t_off = self._run(False)
        t_on = self._run(True)
        assert t_off.configs == t_on.configs
        assert t_off.values == t_on.values

    def test_shard_devices_gate(self):
        cfg = BOConfig(shard_candidates=True)
        strat = BOStrategy(self._space(), cfg)
        devs = strat._shard_devices()
        if len(jax.devices()) == 1:
            assert devs is None              # nothing to shard over
        else:
            assert len(devs) == len(jax.devices())
        strat.cfg = BOConfig(shard_candidates=False)
        assert strat._shard_devices() is None

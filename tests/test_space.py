"""Parameter space + constraint resolution (§3.2): unit + property tests."""

import numpy as np
import pytest

from repro.core import constraints as cres
from repro.core import sampling
from repro.core.knobs import clean_space
from repro.core.space import (Divides, Knob, Leq, ProductLeq, Space, SumLeq)
from repro.configs import get_config
from repro.core.costmodel import SINGLE_POD
from repro.models.config import SHAPES_BY_NAME


def make_space():
    return Space(
        knobs=(
            Knob("a", "int", 8, lo=1, hi=64, log_scale=True),
            Knob("b", "float", 0.5, lo=0.0, hi=1.0),
            Knob("c", "float", 0.3, lo=0.0, hi=1.0),
            Knob("sel", "categorical", "x", choices=("x", "y", "z")),
            Knob("gated", "int", 4, lo=1, hi=16, gated_by=("sel", ("y",))),
            Knob("flag", "bool", True),
            Knob("fixed", "int", 7, lo=7, hi=8, configurable=False),
        ),
        constraints=(SumLeq(("b", "c"), limit=0.9),),
    )


class TestKnob:
    def test_unit_roundtrip_log(self):
        k = Knob("x", "int", 8, lo=1, hi=64, log_scale=True)
        for v in (1, 2, 8, 64):
            assert k.from_unit(k.to_unit(v)) == v

    def test_align(self):
        k = Knob("x", "int", 512, lo=128, hi=2048, align=128)
        assert k.clip(300) == 256
        assert k.clip(5000) == 2048

    def test_expand_dynamic(self):
        k = Knob("x", "float", 8.0, lo=1.0, hi=64.0, log_scale=True,
                 dynamic_bound=True)
        e = k.expanded(2.0)
        assert e.lo < 1.0 and e.hi > 64.0

    def test_expand_static_noop(self):
        k = Knob("x", "float", 8.0, lo=1.0, hi=64.0)
        assert k.expanded(2.0) == k


class TestConstraints:
    def test_sum_leq_projection(self):
        sp = make_space()
        cfg = sp.project({"a": 8, "b": 0.8, "c": 0.8, "sel": "x",
                          "gated": 4, "flag": True, "fixed": 7})
        assert cfg["b"] + cfg["c"] <= 0.9 + 1e-9

    def test_gating_pins_inactive(self):
        sp = make_space()
        cfg = sp.project({"a": 8, "b": 0.1, "c": 0.1, "sel": "x",
                          "gated": 13, "flag": True, "fixed": 7})
        assert cfg["gated"] == 4          # sel != y -> pinned to default
        cfg = sp.project({**cfg, "sel": "y", "gated": 13})
        assert cfg["gated"] == 13

    def test_divides_projection(self):
        sp = Space((Knob("m", "int", 4, lo=1, hi=16),),
                   (Divides(("m",), target=12),))
        assert sp.project({"m": 5})["m"] in (1, 2, 3, 4, 6, 12)

    def test_product_leq(self):
        sp = Space((Knob("p", "int", 512, lo=128, hi=2048, align=128),
                    Knob("q", "int", 512, lo=128, hi=2048, align=128)),
                   (ProductLeq(("p", "q"), limit=512 * 512),))
        cfg = sp.project({"p": 2048, "q": 2048})
        assert cfg["p"] * cfg["q"] <= 512 * 512

    def test_leq(self):
        sp = Space((Knob("lo_", "int", 2, lo=1, hi=64),
                    Knob("hi_", "int", 8, lo=1, hi=64)),
                   (Leq(("lo_", "hi_")),))
        cfg = sp.project({"lo_": 32, "hi_": 8})
        assert cfg["lo_"] <= cfg["hi_"]


class TestResolver:
    def test_wash_removes_unconfigurable(self):
        sp, pins, report = cres.resolve(make_space())
        assert "fixed" not in sp.names
        assert report["washed"] == 1

    def test_prune_gated_by_pin(self):
        sp, pins, _ = cres.resolve(make_space(), pinned={"sel": "x"})
        assert "sel" not in sp.names
        assert "gated" not in sp.names     # sel pinned to x -> y-gated gone

    def test_prune_keeps_enabled(self):
        sp, _, _ = cres.resolve(make_space(), pinned={"sel": "y"})
        assert "gated" in sp.names


# property tests (were hypothesis @given): fixed draws of seeds
@pytest.mark.parametrize(
    "seed,n",
    [(int(s), int(n)) for s, n in zip(
        np.random.default_rng(7).integers(0, 2**31 - 1, 30),
        np.random.default_rng(8).integers(1, 41, 30))])
def test_projection_idempotent_and_valid(seed, n):
    """Property: every sample from the clean domain validates, and
    project() is idempotent (the paper's 'no misconfigurations' claim)."""
    sp = make_space()
    clean, _, _ = cres.resolve(sp)
    for cfg in sampling.random_configs(clean, min(n, 8), seed=seed):
        assert clean.validate(cfg) == []
        assert clean.project(cfg) == cfg


@pytest.mark.parametrize(
    "seed", np.random.default_rng(9).integers(0, 2**31 - 1, 10).tolist())
def test_real_knobspace_samples_valid(seed):
    """The full generated TPU knob space also yields only valid configs."""
    cfg = get_config("yi-6b")
    space, pins, report = clean_space(cfg, SHAPES_BY_NAME["train_4k"],
                                      SINGLE_POD)
    assert report["clean"] > 300          # paper-scale knob count
    assert report["washed"] >= 20         # C1 knobs removed
    for c in sampling.latin_hypercube(space, 4, seed=seed):
        assert space.validate(c) == []


def test_lhs_stratification():
    sp, _, _ = cres.resolve(make_space())
    rng = np.random.default_rng(0)
    u = sampling.lhs_unit(rng, 16, 3)
    # exactly one sample per stratum per dimension
    for d in range(3):
        assert sorted((u[:, d] * 16).astype(int).tolist()) == list(range(16))


def test_dynamic_boundary_detection():
    sp = Space((Knob("x", "float", 8.0, lo=1.0, hi=64.0, log_scale=True,
                     dynamic_bound=True),))
    assert sp.near_boundary({"x": 63.0}) == ["x"]
    assert sp.near_boundary({"x": 8.0}) == []
    sp2 = sp.expand_boundaries(["x"])
    assert sp2.knob("x").hi > 64.0

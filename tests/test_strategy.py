"""Ask/tell SearchStrategy protocol: legacy equivalence, partial tells,
the Controller experiment loop, successive halving, EvalDB hardening."""

import json

import numpy as np
import pytest

from repro.core import bo, optimizers as opt
from repro.core.controller import Controller, EvalDB
from repro.core.space import Knob, Space
from repro.core.strategy import (AnnealingStrategy, BOConfig, BOStrategy,
                                 GAConfig, GeneticStrategy, RandomStrategy,
                                 SAConfig, SearchStrategy, make_strategy,
                                 strategy_names)


def _space():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("k", "int", 4, lo=1, hi=16),
                  Knob("c", "categorical", "a", choices=("a", "b", "c"))))


def _f(c):
    return ((c["x"] - 0.7) ** 2 + (c["y"] - 0.2) ** 2
            + 0.01 * c["k"] + (0.3 if c["c"] == "b" else 0.0))


def _drive(strategy, f):
    while not strategy.finished:
        cfgs = strategy.ask()
        if not cfgs:
            break
        strategy.tell(cfgs, [float(f(c)) for c in cfgs])
    return strategy


def _assert_traces_equal(a, b):
    assert a.configs == b.configs
    assert np.allclose(a.values, b.values)
    assert a.boundary_events == b.boundary_events


# ---------------------------------------------------------------------------
# strategy-vs-legacy equivalence: same seed => identical traces
# ---------------------------------------------------------------------------

class TestLegacyEquivalence:
    @pytest.mark.parametrize("q", [1, 3])
    def test_bo(self, q):
        cfg = BOConfig(n_init=4, n_iter=10, batch_size=q, n_candidates=64,
                       fit_steps=20, seed=7)
        _, _, legacy, legacy_space = bo.minimize(
            _f, _space(), cfg, f_batch=lambda cs: [_f(c) for c in cs])
        strat = _drive(BOStrategy(_space(), cfg), _f)
        _assert_traces_equal(legacy, strat.trace)
        assert legacy_space == strat.space

    def test_bo_dynamic_boundary(self):
        sp = Space((Knob("x", "float", 4.0, lo=1.0, hi=8.0, log_scale=True,
                         dynamic_bound=True),))
        f = lambda c: (c["x"] - 20.0) ** 2            # noqa: E731
        cfg = BOConfig(n_init=4, n_iter=10, n_candidates=128, fit_steps=40,
                       boundary_factor=3.0)
        _, _, legacy, legacy_space = bo.minimize(f, sp, cfg)
        strat = _drive(BOStrategy(sp, cfg), f)
        _assert_traces_equal(legacy, strat.trace)
        assert strat.trace.boundary_events            # expansions happened
        assert legacy_space.knob("x").hi == strat.space.knob("x").hi

    def test_random(self):
        _, _, legacy = opt.random_search(_f, _space(), 20, seed=3)
        strat = _drive(RandomStrategy(_space(), 20, seed=3), _f)
        _assert_traces_equal(legacy, strat.trace)

    def test_sa(self):
        _, _, legacy = opt.simulated_annealing(_f, _space(), 20,
                                               SAConfig(seed=3))
        strat = _drive(AnnealingStrategy(_space(), 20, SAConfig(seed=3)), _f)
        _assert_traces_equal(legacy, strat.trace)

    def test_ga(self):
        _, _, legacy = opt.genetic_algorithm(_f, _space(), 26,
                                             GAConfig(seed=3))
        strat = _drive(GeneticStrategy(_space(), 26, GAConfig(seed=3)), _f)
        _assert_traces_equal(legacy, strat.trace)

    def test_controller_run_matches_legacy_random(self):
        """The experiment loop reproduces the legacy closed loop when the
        evaluator is a plain callable (sequential fallback)."""
        _, _, legacy = opt.random_search(_f, _space(), 16, seed=1)
        ctrl = Controller(_f, EvalDB(), tag="r")
        trace = ctrl.run(RandomStrategy(_space(), 16, seed=1))
        _assert_traces_equal(legacy, trace)
        assert [r.value for r in ctrl.db.records] == trace.values


# ---------------------------------------------------------------------------
# tell: partial batches, out-of-order results, injected observations
# ---------------------------------------------------------------------------

class TestTellSemantics:
    def test_bo_partial_and_out_of_order(self):
        cfg = BOConfig(n_init=4, n_iter=6, batch_size=3, n_candidates=32,
                       fit_steps=10)
        strat = BOStrategy(_space(), cfg)
        init = strat.ask()
        assert len(init) == 4
        # init told in reversed halves
        strat.tell(init[2:][::-1], [_f(c) for c in init[2:][::-1]])
        strat.tell(init[:2], [_f(c) for c in init[:2]])
        probes = strat.ask()
        assert len(probes) == 3
        # partial: two of three results arrive first
        strat.tell(probes[1:], [_f(c) for c in probes[1:]])
        assert not strat.finished
        # the in-flight probe counts against the budget: 6 - 2 told - 1
        more = strat.ask()
        assert len(more) == 3
        strat.tell(more, [_f(c) for c in more])
        strat.tell(probes[:1], [_f(c) for c in probes[:1]])   # straggler
        assert strat.finished
        assert len(strat.trace.values) == 4 + 6

    def test_bo_injected_observations_are_free(self):
        cfg = BOConfig(n_init=2, n_iter=4, n_candidates=32, fit_steps=10)
        strat = BOStrategy(_space(), cfg)
        init = strat.ask()
        strat.tell(init, [_f(c) for c in init])
        # warm-start history the strategy never asked for
        foreign = dict(_space().default_config())
        strat.tell([foreign], [_f(foreign)])
        told = 0
        while not strat.finished:
            ps = strat.ask()
            strat.tell(ps, [_f(c) for c in ps])
            told += len(ps)
        assert told == 4                       # budget unaffected
        assert len(strat.trace.values) == 2 + 1 + 4

    def test_random_partial_tell(self):
        strat = RandomStrategy(_space(), 10, seed=0, batch_size=10)
        cfgs = strat.ask()
        strat.tell(cfgs[5:], [_f(c) for c in cfgs[5:]])
        assert not strat.finished
        strat.tell(cfgs[:5], [_f(c) for c in cfgs[:5]])
        assert strat.finished

    def test_ga_out_of_order_generation(self):
        strat = GeneticStrategy(_space(), 24, GAConfig(seed=0, population=6))
        gen = strat.ask()
        assert len(gen) == 6
        order = [3, 0, 5, 1, 4, 2]
        for i in order:                        # results arrive shuffled
            strat.tell([gen[i]], [_f(gen[i])])
        nxt = strat.ask()                      # evolution still triggers
        assert nxt and len(strat.trace.values) == 6


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_names_and_protocol(self):
        assert {"bo", "random", "sa", "ga"} <= set(strategy_names())
        for name in ("bo", "random", "sa", "ga"):
            s = make_strategy(name, _space(), budget=8, seed=0)
            assert isinstance(s, SearchStrategy)

    def test_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown strategy"):
            make_strategy("hillclimb", _space())

    def test_budget_flows_through(self):
        for name in ("random", "sa", "ga"):
            s = _drive(make_strategy(name, _space(), budget=9, seed=1), _f)
            assert len(s.trace.values) >= 9 and s.finished
        s = _drive(make_strategy("bo", _space(), budget=9, seed=1,
                                 cfg=BOConfig(n_init=3, n_candidates=32,
                                              fit_steps=10)), _f)
        assert len(s.trace.values) == 9        # n_init + (budget - n_init)

    def test_bo_budget_below_design_shrinks_design(self):
        s = _drive(make_strategy("bo", _space(), budget=4, seed=1,
                                 cfg=BOConfig(n_init=8, n_candidates=32,
                                              fit_steps=10)), _f)
        assert len(s.trace.values) == 4 and s.finished


# ---------------------------------------------------------------------------
# Controller.run: budget cap + on_round hook
# ---------------------------------------------------------------------------

class TestControllerRun:
    def test_on_round_hook_and_tags(self):
        rounds = []
        ctrl = Controller(_f, EvalDB(), tag="bo")
        strat = BOStrategy(_space(), BOConfig(n_init=4, n_iter=8,
                                              batch_size=4, n_candidates=32,
                                              fit_steps=10))
        trace = ctrl.run(strat,
                         on_round=lambda i, cfgs, vals: rounds.append(
                             (i, len(cfgs), len(vals))))
        assert rounds == [(0, 4, 4), (1, 4, 4), (2, 4, 4)]
        assert len(trace.values) == 12
        assert all(r.tag == "bo" for r in ctrl.db.records)

    def test_budget_cap(self):
        ctrl = Controller(_f, EvalDB())
        strat = RandomStrategy(_space(), 50, seed=0, batch_size=8)
        trace = ctrl.run(strat, budget=20)
        assert len(trace.values) == 20         # 8 + 8 + truncated 4
        assert not strat.finished

    def test_budget_cap_preserves_strategy_batch_width(self):
        """A run-level budget must cap the spend, not inflate the
        strategy's preferred q-batch into one giant round."""
        rounds = []
        ctrl = Controller(_f, EvalDB())
        strat = BOStrategy(_space(), BOConfig(n_init=4, n_iter=40,
                                              batch_size=3, n_candidates=32,
                                              fit_steps=10))
        ctrl.run(strat, budget=13,
                 on_round=lambda i, cfgs, vals: rounds.append(len(cfgs)))
        assert rounds == [4, 3, 3, 3]          # init + q-rounds, capped

    def test_prepare_records_full_configs(self):
        sub = _space().subset(["x", "y"])
        base = _space().default_config()

        def full(c):
            out = dict(base)
            out.update(c)
            return out

        ctrl = Controller(_f, EvalDB(), tag="s").with_prepare(full)
        ctrl.run(RandomStrategy(sub, 5, seed=0))
        assert all(set(r.config) == set(base) for r in ctrl.db.records)


# ---------------------------------------------------------------------------
# successive halving: the promotion schedule
# ---------------------------------------------------------------------------

class TestSuccessiveHalving:
    def test_promotion_schedule(self):
        low = lambda c: (c["x"] - 0.5) ** 2 + 0.07          # noqa: E731
        high = lambda c: (c["x"] - 0.5) ** 2                # noqa: E731
        db = EvalDB()
        ctrl = Controller(low, db)
        strat = RandomStrategy(_space(), budget=None, seed=0)
        best_c, best_v, sched = ctrl.run_successive_halving(
            strat, high, rounds=3, screen=8, promote=2)
        assert [(s["screened"], s["promoted"]) for s in sched] == [(8, 2)] * 3
        # promoted really are each round's screen argmin-2
        for s in sched:
            top2 = sorted(s["screen_values"])[:2]
            assert np.allclose(sorted(v + 0.07 for v in s["high_values"]),
                               top2)
        tags = [r.tag for r in db.records]
        assert tags.count("screen") == 24 and tags.count("promote") == 6
        # best is over high-fidelity values only
        assert best_v == min(v for s in sched for v in s["high_values"])
        assert abs(high(best_c) - best_v) < 1e-12
        # the strategy was told every screened candidate
        assert len(strat.trace.values) == 24

    def test_bare_high_evaluator_inherits_prepare(self):
        """Both fidelities must score the same completed config: a bare
        high-fidelity callable inherits the screen controller's prepare."""
        sub = _space().subset(["x", "y"])
        full = _space().completer()
        seen = []

        def high(c):
            seen.append(dict(c))
            return c["x"]

        ctrl = Controller(lambda c: c["x"], EvalDB()).with_prepare(full)
        ctrl.run_successive_halving(RandomStrategy(sub, budget=None, seed=0),
                                    high, rounds=2, screen=4, promote=2)
        assert seen and all(set(c) == set(_space().names) for c in seen)

    def test_respects_strategy_budget(self):
        low = lambda c: c["x"]                              # noqa: E731
        ctrl = Controller(low, EvalDB())
        strat = RandomStrategy(_space(), budget=12, seed=0)
        _, _, sched = ctrl.run_successive_halving(
            strat, low, rounds=10, screen=8, promote=2)
        assert [s["screened"] for s in sched] == [8, 4]     # budget drained
        assert strat.finished


# ---------------------------------------------------------------------------
# EvalDB hardening: corrupt trailing lines, numpy round-trips
# ---------------------------------------------------------------------------

class TestEvalDBHardening:
    def test_skips_corrupt_trailing_line(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        db = EvalDB(str(p))
        ctrl = Controller(lambda c: float(c["x"]), db, tag="t")
        ctrl({"x": 1.0})
        ctrl({"x": 2.0})
        with p.open("a") as f:                 # crashed writer artifact
            f.write('{"config": {"x": 3.0}, "val\n')
        with pytest.warns(UserWarning, match="corrupt line"):
            db2 = EvalDB(str(p))
        assert [r.value for r in db2.records] == [1.0, 2.0]
        # the reloaded DB keeps appending cleanly
        Controller(lambda c: float(c["x"]), db2, tag="t")({"x": 4.0})
        with pytest.warns(UserWarning):
            assert len(EvalDB(str(p))) == 3

    def test_skips_non_json_garbage_line(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        p.write_text('not json at all\n'
                     '{"config": {"x": 1}, "value": 2.0}\n'
                     '{"config": {"x": 5}}\n')              # missing value
        with pytest.warns(UserWarning):
            db = EvalDB(str(p))
        assert len(db) == 1 and db.records[0].value == 2.0

    def test_numpy_configs_roundtrip_equal(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        db = EvalDB(str(p))
        ctrl = Controller(lambda c: 1.5, db, tag="t")
        ctrl.evaluate_batch([{"a": np.int64(3), "b": np.float32(0.25),
                              "c": np.bool_(True), "d": "flash"}])
        fresh = {"a": 3, "b": 0.25, "c": True, "d": "flash"}
        # in-memory record, the JSONL, and the reload all agree
        assert db.records[0].config == fresh
        assert json.loads(p.read_text())["config"] == fresh
        assert EvalDB(str(p)).records[0].config == fresh


# ---------------------------------------------------------------------------
# completer / overlaid: dynamic-boundary probes must reach the evaluator
# ---------------------------------------------------------------------------

class TestCompleter:
    def _full_space(self):
        return Space((Knob("x", "float", 4.0, lo=1.0, hi=8.0,
                           dynamic_bound=True),
                      Knob("y", "float", 0.5, lo=0.0, hi=1.0)))

    def test_completer_pins_and_projects(self):
        sp = self._full_space()
        out = sp.completer()({"x": 6.0})
        assert out == {"x": 6.0, "y": 0.5}

    def test_plain_completer_clips_expanded_probe(self):
        sp = self._full_space()
        assert sp.completer()({"x": 12.0})["x"] == 8.0

    def test_overlaid_completer_respects_expanded_bounds(self):
        sp = self._full_space()
        expanded = sp.subset(["x"]).expand_boundaries(["x"], factor=3.0)
        assert expanded.knob("x").hi > 8.0
        out = sp.overlaid(expanded).completer()({"x": 12.0})
        assert out["x"] == 12.0                 # unclipped
        assert out["y"] == 0.5                  # non-top knob still pinned

    def test_sapphire_search_prepare_follows_boundary_events(self):
        """End-to-end: when the BO strategy enlarges a dynamic boundary,
        the evaluator sees the enlarged probe values (DB records them)."""
        from repro.core.strategy import BOStrategy

        sp = self._full_space()
        db = EvalDB()
        seen = []

        def evaluator(c):
            seen.append(dict(c))
            return (c["x"] - 20.0) ** 2         # optimum outside the box

        strat = BOStrategy(sp.subset(["x"]),
                           BOConfig(n_init=4, n_iter=12, n_candidates=128,
                                    fit_steps=30, boundary_factor=3.0))
        cache = {}

        def prepare(sub_cfg):
            if cache.get("sub") is not strat.space:
                cache["sub"] = strat.space
                cache["complete"] = sp.overlaid(strat.space).completer()
            return cache["complete"](sub_cfg)

        Controller(evaluator, db).with_prepare(prepare).run(strat)
        assert strat.trace.boundary_events      # enlargement happened
        assert max(c["x"] for c in seen) > 8.0  # ...and reached the evaluator
        assert max(r.config["x"] for r in db.records) > 8.0


# ---------------------------------------------------------------------------
# dynamic-boundary damping: wide waves must not over-inflate the domain
# ---------------------------------------------------------------------------

class TestBoundaryDamping:
    def _dyn_space(self):
        return Space((Knob("a", "float", 4.0, lo=1.0, hi=8.0,
                           dynamic_bound=True),
                      Knob("b", "float", 4.0, lo=1.0, hi=8.0,
                           dynamic_bound=True)))

    def test_simultaneous_events_are_damped(self):
        """Two knobs triggering in ONE round each expand by factor**(1/2):
        the round's domain-volume growth stays at `boundary_factor`
        instead of factor²."""
        strat = BOStrategy(self._dyn_space(), BOConfig(boundary_factor=4.0))
        near = strat._expand_near([{"a": 7.9, "b": 7.9}])
        assert sorted(near) == ["a", "b"]
        # span 7, damped factor 4**(1/2)=2: hi' = 8 + 7·(2-1)/2 = 11.5
        assert strat.space.knob("a").hi == pytest.approx(11.5)
        assert strat.space.knob("b").hi == pytest.approx(11.5)
        assert len(strat.trace.boundary_events) == 2

    def test_single_event_keeps_full_factor(self):
        strat = BOStrategy(self._dyn_space(), BOConfig(boundary_factor=4.0))
        near = strat._expand_near([{"a": 7.9, "b": 4.0}])
        assert near == ["a"]
        # span 7, full factor 4: hi' = 8 + 7·(4-1)/2 = 18.5
        assert strat.space.knob("a").hi == pytest.approx(18.5)
        assert strat.space.knob("b").hi == 8.0

    def test_damping_off_restores_legacy(self):
        strat = BOStrategy(self._dyn_space(),
                           BOConfig(boundary_factor=4.0,
                                    boundary_damping=False))
        strat._expand_near([{"a": 7.9, "b": 7.9}])
        assert strat.space.knob("a").hi == pytest.approx(18.5)
        assert strat.space.knob("b").hi == pytest.approx(18.5)


# ---------------------------------------------------------------------------
# keyed pending probes: O(q) tells, dict-equality semantics preserved
# ---------------------------------------------------------------------------

class TestPendingKeying:
    def test_fifo_payloads_and_counts(self):
        from repro.core.strategy import _PendingSet
        ps = _PendingSet()
        cfg = {"x": 0.5, "k": 4}
        ps.add(cfg, "first")
        ps.add(dict(cfg), "second")             # duplicate probe
        ps.add({"x": 0.9, "k": 4}, "other")
        assert len(ps) == 3 and ps
        assert ps.pop({"k": 4, "x": 0.5}) == (True, "first")   # key order
        assert ps.pop(cfg) == (True, "second")                 # FIFO
        assert ps.pop(cfg) == (False, None)     # now an injected obs
        assert len(ps) == 1

    def test_numpy_scalars_match_python_values(self):
        from repro.core.strategy import _PendingSet
        ps = _PendingSet()
        ps.add({"x": np.float64(0.5), "k": np.int64(4), "b": np.bool_(True)})
        assert ps.pop({"x": 0.5, "k": 4, "b": True})[0]
        assert len(ps) == 0

    def test_wide_wave_tell_is_linear(self):
        """A q-wide out-of-order tell costs O(q) bucket pops, not O(q·n)
        list scans — same observable behavior as the legacy path."""
        strat = RandomStrategy(_space(), 64, seed=0, batch_size=64)
        cfgs = strat.ask()
        strat.tell(cfgs[::-1], [_f(c) for c in cfgs[::-1]])
        assert strat.finished
        assert len(strat._pending) == 0


# ---------------------------------------------------------------------------
# background GP refit: ask() never blocks on the Adam loop
# ---------------------------------------------------------------------------

class TestRefitAsync:
    def _patch_slow_fit(self, monkeypatch, delay, calls):
        import threading
        import time as _time

        from repro.core import gp as gp_mod
        real_fit = gp_mod.fit

        def slow_fit(*a, **k):
            calls.append(threading.current_thread().name)
            _time.sleep(delay)
            return real_fit(*a, **k)

        monkeypatch.setattr(gp_mod, "fit", slow_fit)

    def test_ask_uses_stale_posterior_without_blocking(self, monkeypatch):
        import time as _time

        delay = 0.4
        calls = []
        self._patch_slow_fit(monkeypatch, delay, calls)
        cfg = BOConfig(n_init=4, n_iter=8, batch_size=2, n_candidates=32,
                       fit_steps=5, refit_async=True)
        strat = BOStrategy(_space(), cfg)
        init = strat.ask()
        strat.tell(init, [_f(c) for c in init])
        p1 = strat.ask()                 # first BO ask: synchronous fit
        assert p1
        strat.tell(p1, [_f(c) for c in p1])
        t0 = _time.monotonic()
        p2 = strat.ask()                 # stale posterior, background refit
        dt = _time.monotonic() - t0
        assert p2 and dt < delay / 2
        strat.tell(p2, [_f(c) for c in p2])
        while not strat.finished:        # completes despite staleness
            ps = strat.ask()
            strat.tell(ps, [_f(c) for c in ps])
        strat.close()
        assert any("gp-refit" in name for name in calls)
        assert len(strat.trace.values) == 4 + 8

    def test_run_async_submission_independent_of_fit(self, monkeypatch):
        """The acceptance property: with refit_async the overlapped
        loop's submission latency does not contain the fit — at most the
        one synchronous first-round fit exceeds a fraction of the fit
        delay."""
        delay = 0.3
        calls = []
        self._patch_slow_fit(monkeypatch, delay, calls)
        cfg = BOConfig(n_init=4, n_iter=6, batch_size=2, n_candidates=32,
                       fit_steps=5, refit_async=True)
        strat = BOStrategy(_space(), cfg)
        lat = []
        ctrl = Controller(_f, EvalDB())
        trace = ctrl.run_async(strat, on_ask=lambda n, s: lat.append(s))
        strat.close()
        assert len(trace.values) == 4 + 6
        slow = [s for s in lat if s > delay / 2]
        assert len(slow) <= 1            # only the first-round sync fit


# ---------------------------------------------------------------------------
# refit staleness across boundary expansion: the background fit must see
# the trace re-encoded in the *current* space, and a space change alone
# (same observation count) must trigger a fresh refit
# ---------------------------------------------------------------------------

class TestRefitSpaceStaleness:
    def _dyn_space(self):
        return Space((Knob("a", "float", 4.0, lo=1.0, hi=8.0,
                           dynamic_bound=True),
                      Knob("b", "float", 4.0, lo=1.0, hi=8.0,
                           dynamic_bound=True)))

    def _seed(self, strat):
        init = strat.ask()
        strat.tell(init, [float(8.0 - c["a"]) for c in init])
        return init

    def test_space_change_alone_rekicks_refit(self):
        """Boundary expansion re-encodes every stored config, so a refit
        over the old unit-cube coordinates is stale even when no new
        observation arrived.  The version tracker must kick a fresh fit
        on the re-encoded snapshot."""
        cfg = BOConfig(n_init=3, n_iter=6, fit_steps=5, n_candidates=16,
                       refit_async=True, dynamic_boundary=True)
        strat = BOStrategy(self._dyn_space(), cfg)
        self._seed(strat)
        x = strat.space.encode_batch(strat.trace.configs)
        y = np.asarray(strat.trace.values, float)
        strat._refit(x, y)                   # sync fit levels both trackers
        strat._refit_kick(x, y)
        assert strat._refit_future is None   # nothing new: no kick
        near = strat._expand_near([{"a": 7.9, "b": 4.0}])
        assert near == ["a"]
        x2 = strat.space.encode_batch(strat.trace.configs)
        assert not np.allclose(x, x2)        # expansion moved the encoding
        strat._refit_kick(x2, y)
        assert strat._refit_future is not None   # same obs count, new space
        strat._refit_future.result()
        assert np.allclose(strat._refit_snapshot[0], x2)
        strat.close()

    def test_ask_reencodes_snapshot_after_expansion(self, monkeypatch):
        """End-to-end through ask(): a round that enlarges a boundary
        must hand the background fit the trace encoded in the *enlarged*
        space, not the coordinates selection ran against."""
        monkeypatch.setattr(Space, "near_boundary",
                            lambda self, cfg, tol=0.05: ["a"])
        cfg = BOConfig(n_init=3, n_iter=6, batch_size=2, fit_steps=5,
                       n_candidates=16, refit_async=True,
                       dynamic_boundary=True)
        strat = BOStrategy(self._dyn_space(), cfg)
        self._seed(strat)
        hi_before = strat.space.knob("a").hi
        probes = strat.ask()                 # sync first fit + expansion
        assert probes
        assert strat.space.knob("a").hi > hi_before
        assert strat._refit_future is not None
        strat._refit_future.result()
        want = strat.space.encode_batch(strat.trace.configs)
        assert np.allclose(strat._refit_snapshot[0], want)
        strat.close()

    def test_refit_device_selection(self):
        import jax

        from repro.parallel.sharding import spare_device

        devs = jax.devices()
        pinned = BOStrategy(_space(), BOConfig(refit_device=0))
        assert pinned._refit_device() == devs[0]
        wrap = BOStrategy(_space(), BOConfig(refit_device=len(devs)))
        assert wrap._refit_device() == devs[0]    # modular, never IndexError
        auto = BOStrategy(_space(), BOConfig())
        assert auto._refit_device() == spare_device()

"""repro.transfer: corpus assembly over evaluation logs, the ICM
multi-task GP prior, TransferBOStrategy's warm-start prongs, and the
load_state space-identity guards the snapshot/resume path leans on.

The empty-corpus identity tests use deterministic objectives: the
trace-identity contract is about the *strategy's* draws, and an unseeded
noisy evaluator would feed the two runs different values."""

import json
import warnings

import numpy as np
import pytest

from repro.core import gp
from repro.core.controller import EvalRecord
from repro.core.space import Knob, Space
from repro.core.strategy import (BOConfig, BOStrategy, make_strategy,
                                 strategy_names)
from repro.transfer import (CorpusMismatch, TaskData, TransferBOStrategy,
                            TransferCorpus, build_corpus, corpus_from_log,
                            space_signature)


def _space():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0)))


def _f(c, shift=0.0):
    return (c["x"] - 0.3) ** 2 + (c["y"] - 0.7) ** 2 + 0.05 + shift


def _records(workload, pts, shift=0.0, variance=0.0, status="ok"):
    return [EvalRecord({"x": float(px), "y": float(py)},
                       _f({"x": px, "y": py}, shift), 0.0, "t", workload,
                       "final", status, 1, variance)
            for px, py in pts]


def _grid(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.05, 0.95, size=(n, 2))


def _drive(strategy, f):
    while not strategy.finished:
        cfgs = strategy.ask()
        if not cfgs:
            break
        strategy.tell(cfgs, [float(f(c)) for c in cfgs])
    return strategy


SMALL_BO = dict(n_init=3, n_iter=3, n_candidates=32, fit_steps=10, seed=5)


# ---------------------------------------------------------------------------
# multi-task GP
# ---------------------------------------------------------------------------

class TestMultiTaskGP:
    def _data(self, n=14, seed=0):
        x = _grid(n, seed).astype(np.float64)
        y0 = np.array([_f({"x": a, "y": b}) for a, b in x])
        y1 = y0 + 0.5                       # same landscape, shifted level
        xx = np.vstack([x, x])
        yy = np.concatenate([y0, y1])
        tt = np.concatenate([np.zeros(n, np.int32),
                             np.ones(n, np.int32)])
        return xx, yy, tt

    def test_fit_predict_tracks_each_task(self):
        x, y, t = self._data()
        st = gp.fit_multitask(x, y, t, steps=80)
        xq = _grid(6, seed=3).astype(np.float32)
        mu0, sd0 = gp.predict_multitask(st, xq, task=0)
        mu1, sd1 = gp.predict_multitask(st, xq, task=1)
        truth = np.array([_f({"x": a, "y": b}) for a, b in xq])
        assert np.all(np.asarray(sd0) > 0)
        # the learned per-task offsets carry the level difference
        assert np.mean(np.asarray(mu1) - np.asarray(mu0)) > 0.25
        assert np.mean(np.abs(np.asarray(mu0) - truth)) < 0.2

    def test_stacked_prior_for_unseen_task(self):
        x, y, t = self._data()
        st = gp.fit_multitask(x, y, t, steps=80)
        xq = _grid(5, seed=4).astype(np.float32)
        mu, sd = gp.predict_multitask(st, xq, task=None)
        mu0, _ = gp.predict_multitask(st, xq, task=0)
        mu1, _ = gp.predict_multitask(st, xq, task=1)
        assert np.all(np.isfinite(np.asarray(mu)))
        assert np.all(np.asarray(sd) > 0)
        lo = np.minimum(np.asarray(mu0), np.asarray(mu1)) - 0.2
        hi = np.maximum(np.asarray(mu0), np.asarray(mu1)) + 0.2
        assert np.all((np.asarray(mu) >= lo) & (np.asarray(mu) <= hi))

    def test_fit_routes_on_task_column(self):
        x, y, t = self._data()
        st = gp.fit(x, y, tasks=t, steps=20, pad=False)
        assert isinstance(st, gp.MTGPState)

    def test_single_task_fallback_is_exact(self):
        x = _grid(10).astype(np.float64)
        y = np.array([_f({"x": a, "y": b}) for a, b in x])
        plain = gp.fit(x, y, steps=25, pad=False)
        tasked = gp.fit(x, y, tasks=np.zeros(len(y), np.int32),
                        steps=25, pad=False)
        assert isinstance(tasked, gp.GPState)
        assert np.allclose(np.asarray(plain.alpha),
                           np.asarray(tasked.alpha))

    def test_multitask_warm_start_needs_mt_params(self):
        x, y, t = self._data()
        with pytest.raises(TypeError, match="MTGPParams"):
            gp.fit(x, y, tasks=t, steps=5, params=gp.init_params(2))

    def test_tasks_row_mismatch(self):
        x, y, t = self._data()
        with pytest.raises(ValueError, match="rows"):
            gp.fit(x, y, tasks=t[:-1], steps=5)

    def test_params_dict_roundtrip(self):
        p = gp.init_mt_params(3, 2, offsets=np.array([0.1, -0.2]))
        d = gp.mt_params_to_dict(p)
        json.dumps(d)                        # wire-serializable
        q = gp.mt_params_from_dict(d)
        for a, b in zip(p, q):
            assert np.allclose(np.asarray(a), np.asarray(b))

    def test_shared_params_projection(self):
        x, y, t = self._data()
        st = gp.fit_multitask(x, y, t, steps=20)
        sp = gp.shared_params(st.params)
        assert isinstance(sp, gp.GPParams)
        assert np.allclose(np.asarray(sp.log_lengthscale),
                           np.asarray(st.params.log_lengthscale))

    def test_heteroscedastic_rows_downweighted(self):
        x, y, t = self._data(n=10)
        var = np.zeros(len(y))
        y_noisy = y.copy()
        y_noisy[3] += 5.0                    # wild outlier...
        var[3] = 25.0                        # ...flagged as such
        st = gp.fit_multitask(x, y_noisy, t, steps=40, obs_var=var)
        st_trust = gp.fit_multitask(x, y_noisy, t, steps=40)
        xq = x[3][None].astype(np.float32)
        mu_down, _ = gp.predict_multitask(st, xq, task=0)
        mu_trust, _ = gp.predict_multitask(st_trust, xq, task=0)
        # the flagged fit pulls the outlier's posterior toward the rest
        assert abs(float(mu_down[0]) - y_noisy[3]) > \
            abs(float(mu_trust[0]) - y_noisy[3]) - 1e-6


# ---------------------------------------------------------------------------
# corpus assembly
# ---------------------------------------------------------------------------

class TestCorpusBuild:
    def test_groups_by_workload(self):
        recs = (_records("a", _grid(4)) + _records("b", _grid(3), shift=1.0))
        corpus = build_corpus(_space(), [recs])
        assert corpus.workloads == ("a", "b")
        assert len(corpus) == 7 and bool(corpus)
        a = corpus.tasks[0]
        assert isinstance(a, TaskData) and len(a) == 4
        cfg, val = a.best
        assert val == min(a.values) and _f(cfg) == val

    def test_exclude_and_unstamped(self):
        recs = (_records("a", _grid(4)) + _records("b", _grid(4))
                + _records("", _grid(2)))
        corpus = build_corpus(_space(), [recs], exclude=("b",))
        assert corpus.workloads == ("a",)

    def test_signature_mismatch_skips_loudly(self):
        other = Space((Knob("x", "float", 0.5, lo=0.0, hi=2.0),
                       Knob("y", "float", 0.5, lo=0.0, hi=1.0)))
        assert space_signature(other) != space_signature(_space())
        recs = _records("a", _grid(4)) + _records("b", _grid(4))
        with pytest.warns(CorpusMismatch, match="incompatible space"):
            corpus = build_corpus(_space(), [recs],
                                  spaces={"a": other})
        assert corpus.workloads == ("b",)

    def test_declared_matching_space_keeps_task(self):
        recs = _records("a", _grid(4))
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            corpus = build_corpus(_space(), [recs],
                                  spaces={"a": _space()})
        assert corpus.workloads == ("a",)

    def test_bad_rows_dropped_with_warning(self):
        good = _records("a", _grid(4))
        bad = [EvalRecord({"x": 0.5, "z": 0.5}, 1.0, 0.0, "t", "a"),
               EvalRecord({"x": 5.0, "y": 0.5}, 1.0, 0.0, "t", "a"),
               EvalRecord({"x": 0.5, "y": 0.5}, float("nan"), 0.0,
                          "t", "a"),
               EvalRecord({"x": 0.5, "y": 0.5}, 1.0, 0.0, "t", "a",
                          status="failed")]
        with pytest.warns(CorpusMismatch, match="dropped 2"):
            corpus = build_corpus(_space(), [good + bad])
        assert len(corpus.tasks[0]) == 4     # nan/failed skip silently,
                                             # misfit configs warn

    def test_min_points_drops_thin_tasks(self):
        recs = _records("a", _grid(4)) + _records("thin", _grid(1))
        with pytest.warns(CorpusMismatch, match="thin"):
            corpus = build_corpus(_space(), [recs], min_points=2)
        assert corpus.workloads == ("a",)

    def test_sources_files_dirs_missing(self, tmp_path):
        from repro.core.controller import EvalDB
        db_a = tmp_path / "a.jsonl"
        db_b = tmp_path / "sub" / "b.jsonl"
        db_b.parent.mkdir()
        EvalDB(str(db_a)).append_batch(_records("a", _grid(3)))
        EvalDB(str(db_b)).append_batch(_records("b", _grid(3)))
        corpus = build_corpus(_space(), [str(db_a), db_b.parent])
        assert corpus.workloads == ("a", "b")
        with pytest.warns(CorpusMismatch, match="does not exist"):
            empty = build_corpus(_space(), [tmp_path / "nope.jsonl"])
        assert not empty and empty.n_tasks == 0

    def test_corpus_from_log_object(self):
        class _Log:
            records = _records("a", _grid(3))
        assert corpus_from_log(_space(), _Log()).workloads == ("a",)

    def test_best_configs_interleaves_best_first(self):
        corpus = build_corpus(_space(), [
            _records("worse", _grid(3), shift=1.0)
            + _records("better", _grid(3))])
        seeds = corpus.best_configs(per_task=2)
        assert len(seeds) == 4
        better, worse = corpus.tasks      # sorted: "better" < "worse"
        assert better.best[1] < worse.best[1]
        assert seeds[0] == better.best[0]     # overall best leads
        assert seeds[1] == worse.best[0]      # then the other task's best
        assert seeds[2] == better.top(2)[1]   # round 2: each task's 2nd

    def test_stacked_log_transform_and_tasks(self):
        corpus = build_corpus(_space(), [
            _records("a", _grid(3), variance=0.01)
            + _records("b", _grid(2), shift=1.0)])
        x, y, var, t = corpus.stacked(log_objective=True)
        assert x.shape == (5, 2) and t.tolist() == [0, 0, 0, 1, 1]
        raw = np.concatenate([corpus.tasks[0].values,
                              corpus.tasks[1].values])
        assert np.allclose(y, np.log(raw))
        assert np.allclose(var[:3], 0.01 / raw[:3] ** 2)   # delta method
        x2, y2, _, _ = corpus.stacked(log_objective=False)
        assert np.allclose(y2, raw) and np.allclose(x, x2)

    def test_stacked_max_per_task_keeps_best(self):
        corpus = build_corpus(_space(), [_records("a", _grid(32))])
        x, y, _, _ = corpus.stacked(max_per_task=8, seed=1)
        assert x.shape[0] == 8
        assert min(y) == pytest.approx(np.log(min(corpus.tasks[0].values)))

    def test_stacked_empty(self):
        corpus = TransferCorpus(_space(), [])
        x, y, var, t = corpus.stacked()
        assert x.shape == (0, 2) and len(y) == len(var) == len(t) == 0


# ---------------------------------------------------------------------------
# TransferBOStrategy
# ---------------------------------------------------------------------------

class TestTransferBO:
    def _corpus(self, n_tasks=2, n=10):
        recs = []
        for i in range(n_tasks):
            recs += _records(f"wl{i}", _grid(n, seed=i), shift=0.1 * i)
        return build_corpus(_space(), [recs])

    def test_empty_corpus_identical_to_plain_bo(self):
        cfg = BOConfig(**SMALL_BO)
        plain = _drive(BOStrategy(_space(), cfg), _f)
        empty = TransferCorpus(_space(), [])
        xfer = _drive(TransferBOStrategy(_space(), cfg, corpus=empty), _f)
        assert xfer.trace.configs == plain.trace.configs
        assert np.allclose(xfer.trace.values, plain.trace.values)
        none = _drive(TransferBOStrategy(_space(), BOConfig(**SMALL_BO)),
                      _f)
        assert none.trace.configs == plain.trace.configs

    def test_corpus_bests_seed_the_design(self):
        corpus = self._corpus()
        strat = TransferBOStrategy(_space(), BOConfig(**SMALL_BO),
                                   corpus=corpus, corpus_fit_steps=20)
        first = strat.ask()
        bests = [corpus.tasks[0].best[0], corpus.tasks[1].best[0]]
        planted = [c for c in first
                   if any(np.isclose(c["x"], b["x"])
                          and np.isclose(c["y"], b["y"]) for b in bests)]
        assert len(planted) >= 1

    def test_pseudo_rows_never_reach_the_trace(self):
        corpus = self._corpus()
        strat = TransferBOStrategy(_space(), BOConfig(**SMALL_BO),
                                   corpus=corpus, corpus_fit_steps=20)
        assert strat._pseudo_configs          # prior active
        _drive(strat, _f)
        budget = SMALL_BO["n_init"] + SMALL_BO["n_iter"]
        assert len(strat.trace.values) == budget
        cfgs, vals, vrs = strat._training_data()
        assert len(cfgs) == budget + len(strat._pseudo_configs)
        cfg, val = strat.best()
        assert val == pytest.approx(_f(cfg))  # a real measurement

    def test_pseudo_variance_decays_with_evidence(self):
        corpus = self._corpus()
        strat = TransferBOStrategy(_space(), BOConfig(**SMALL_BO),
                                   corpus=corpus, corpus_fit_steps=20,
                                   decay_tau=2.0)
        _, _, before = strat._training_data()
        cfgs = strat.ask()
        strat.tell(cfgs, [float(_f(c)) for c in cfgs])
        _, _, after = strat._training_data()
        assert after[-1] / before[-1] == pytest.approx(
            np.exp(len(cfgs) / 2.0))

    def test_prior_params_warm_start_without_cfg_flag(self):
        corpus = self._corpus()
        cfg = BOConfig(**SMALL_BO)
        assert not cfg.warm_start
        strat = TransferBOStrategy(_space(), cfg, corpus=corpus,
                                   corpus_fit_steps=20)
        warm, steps = strat._fit_args()
        assert warm is strat._params and warm is not None
        assert steps == cfg.fit_steps

    def test_space_mismatch_raises(self):
        other = Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                       Knob("z", "float", 0.5, lo=0.0, hi=1.0)))
        corpus = self._corpus()
        with pytest.raises(ValueError, match="knob set"):
            TransferBOStrategy(other, BOConfig(**SMALL_BO), corpus=corpus)

    def test_registry(self):
        assert "transfer_bo" in strategy_names()
        corpus = self._corpus()
        strat = make_strategy("transfer_bo", _space(), budget=6, seed=5,
                              cfg=BOConfig(**SMALL_BO), corpus=corpus,
                              corpus_fit_steps=20)
        assert isinstance(strat, TransferBOStrategy)
        assert strat.cfg.n_init + strat.cfg.n_iter == 6

    def test_single_task_corpus_prior(self):
        corpus = self._corpus(n_tasks=1)
        strat = TransferBOStrategy(_space(), BOConfig(**SMALL_BO),
                                   corpus=corpus, corpus_fit_steps=20)
        assert strat._prior is not None and not strat._prior.multitask
        assert strat._pseudo_configs
        _drive(strat, _f)
        assert strat.best()[1] <= _f(strat.trace.configs[0]) + 1e-9

    def test_transfer_finds_optimum_faster_in_design(self):
        # siblings share the optimum at (0.3, 0.7): the seeded design's
        # very first wave should already be near it
        corpus = self._corpus(n_tasks=3, n=24)
        strat = TransferBOStrategy(_space(), BOConfig(**SMALL_BO),
                                   corpus=corpus, corpus_fit_steps=20)
        first = strat.ask()
        best_seed = min(_f(c) for c in first)
        corpus_best = min(t.best[1] for t in corpus.tasks)
        assert best_seed <= corpus_best + 1e-9


# ---------------------------------------------------------------------------
# load_state space-identity guards
# ---------------------------------------------------------------------------

def _dyn_space():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0,
                       dynamic_bound=True)))


class TestLoadStateGuards:
    def _snapshot(self, space=None, cfg=None):
        strat = BOStrategy(space or _space(), cfg or BOConfig(**SMALL_BO))
        cfgs = strat.ask()
        strat.tell(cfgs, [float(_f(c)) for c in cfgs])
        return strat, strat.state_dict()

    def test_roundtrip_restores(self):
        strat, sd = self._snapshot()
        twin = BOStrategy(_space(), BOConfig(**SMALL_BO))
        twin.load_state(sd)
        assert twin.trace.configs == strat.trace.configs
        assert np.allclose(twin.trace.values, strat.trace.values)

    def test_knob_renamed_raises(self):
        _, sd = self._snapshot()
        renamed = Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                         Knob("y2", "float", 0.5, lo=0.0, hi=1.0)))
        with pytest.raises(ValueError, match="space mismatch"):
            BOStrategy(renamed, BOConfig(**SMALL_BO)).load_state(sd)

    def test_base_bounds_widened_raises(self):
        _, sd = self._snapshot()
        widened = Space((Knob("x", "float", 0.5, lo=0.0, hi=2.0),
                         Knob("y", "float", 0.5, lo=0.0, hi=1.0)))
        with pytest.raises(ValueError, match="base bounds differ"):
            BOStrategy(widened, BOConfig(**SMALL_BO)).load_state(sd)

    def test_kernel_changed_raises(self):
        _, sd = self._snapshot()
        other = BOStrategy(_space(), BOConfig(kernel="rbf", **SMALL_BO))
        with pytest.raises(ValueError, match="kernel"):
            other.load_state(sd)

    def test_dynamic_bound_restore_still_works(self):
        strat = BOStrategy(_dyn_space(), BOConfig(**SMALL_BO))
        cfgs = strat.ask()
        strat.tell(cfgs, [float(_f(c)) for c in cfgs])
        # simulate a boundary expansion having happened
        k = strat.space.knob("y")
        from dataclasses import replace as _rp
        strat.space = strat.space.with_knob(_rp(k, hi=2.0))
        sd = strat.state_dict()
        twin = BOStrategy(_dyn_space(), BOConfig(**SMALL_BO))
        twin.load_state(sd)
        assert twin.space.knob("y").hi == 2.0     # dynamic state restored
        assert twin._base_bounds["y"] == (0.0, 1.0)

    def test_legacy_state_without_guards_loads(self):
        strat, sd = self._snapshot()
        sd.pop("knobs")
        sd.pop("base_bounds")
        twin = BOStrategy(_space(), BOConfig(**SMALL_BO))
        twin.load_state(sd)                       # backward compatible
        assert twin.trace.configs == strat.trace.configs

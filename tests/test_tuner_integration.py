"""End-to-end SAPPHIRE integration (Fig. 3 pipeline) + roofline parser."""

import pytest

from repro.core.bo import BOConfig
from repro.core.controller import Controller, EvalDB
from repro.core.tuner import Sapphire, expert_manual_config
from repro.launch.roofline import analyze_hlo


class TestSapphireEndToEnd:
    @pytest.fixture(scope="class")
    def result(self):
        # the paper's own budgets: ~300 ranking samples, ~40+ BO iters.
        # (half-budget runs converge on most seeds but not all — seed
        # variance at tiny budgets is expected of BO, not a defect)
        s = Sapphire(arch="yi-6b", shape="train_4k", top_k=16,
                     n_rank_samples=200,
                     bo_config=BOConfig(n_init=10, n_iter=40,
                                        n_candidates=1024, fit_steps=80,
                                        seed=3),
                     seed=3)
        return s.tune()

    def test_beats_default(self, result):
        """The paper's headline: recommended >> default."""
        assert result.speedup_vs_default > 1.5

    def test_influential_knobs_found(self, result):
        top = set(result.ranking.top(16))
        assert {"tensor_parallel", "matmul_precision"} & top

    def test_recommended_config_is_valid(self, result):
        errs = result.final_space.validate(
            {k: v for k, v in result.best_config.items()
             if k in result.final_space.names})
        assert errs == []

    def test_summary_fields(self, result):
        s = result.summary()
        assert s["clean_domain"]["clean"] > 300
        assert s["n_evaluations"] == 200 + 10 + 40

    def test_eval_budget_respected(self, result):
        # ranking samples + BO evals only: the default/expert baseline
        # probes no longer inflate the reported tuning budget
        assert result.n_evaluations < 300


def test_controller_db_roundtrip(tmp_path):
    db_file = tmp_path / "evals.jsonl"
    db = EvalDB(str(db_file))
    ctrl = Controller(lambda c: float(c["x"]) * 2, db, tag="t")
    assert ctrl({"x": 3}) == 6.0
    assert ctrl({"x": 4}) == 8.0
    db2 = EvalDB(str(db_file))
    cfgs, vals = db2.pairs("t")
    assert vals == [6.0, 8.0]
    assert cfgs[0]["x"] == 3


def test_expert_config_valid():
    from repro.configs import get_config
    from repro.core import knobs as km
    from repro.core.costmodel import SINGLE_POD
    from repro.models.config import SHAPES_BY_NAME
    space, _, _ = km.clean_space(get_config("yi-6b"),
                                 SHAPES_BY_NAME["train_4k"], SINGLE_POD)
    cfg = expert_manual_config(space)
    assert space.validate(cfg) == []
    assert cfg["attention_impl"] == "flash"


# ---------------------------------------------------------------------------
# roofline HLO parser (on hand-written HLO)
# ---------------------------------------------------------------------------

SYNTH_HLO = """\
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> f32[8,8] {
  %arg = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %arg)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_roofline_trip_counted_flops():
    r = analyze_hlo(SYNTH_HLO)
    assert r.flops == 12 * 2 * 8 * 8 * 8            # 12 trips × dot
    assert r.collective_bytes == 12 * 8 * 8 * 4     # 12 × all-reduce
    assert r.trip_counts.get("body") == 12
    assert "all-reduce" in r.coll_by_kind


def test_roofline_dominant_classification():
    r = analyze_hlo(SYNTH_HLO)
    assert r.dominant in ("compute", "memory", "collective")
    assert r.step_s >= max(r.compute_s, r.memory_s, r.collective_s)

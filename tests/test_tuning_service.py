"""Tuning-as-a-service: session isolation, the cross-session probe
cache, the sharded namespaced log, the HTTP wire, and the satellite
state-serialization / replication-prior / file-lock changes.

Conventions follow ``test_service_async.py``: every test runs under a
120 s SIGALRM watchdog so a deadlocked gather/poll (the failure mode of
a multiplexed pool) fails fast instead of hanging CI.
"""

import json
import signal
import threading

import numpy as np
import pytest

from repro.core.controller import Controller, EvalDB, EvalRecord
from repro.core.replication import (AdaptiveRacer, RepeatStats,
                                    ReplicationPolicy)
from repro.core.service import (EvalRequest, EvalResult, EvalTicket,
                                ImmediateEvaluationService, fold_seed)
from repro.core.space import (Divides, Knob, Leq, ProductLeq, Space,
                              SumLeq)
from repro.core.strategy import BOConfig, BOStrategy, make_strategy
from repro.service import (ProbeCache, SessionClosed, ShardedEvalLog,
                           SharedEvaluationPool, TuningClient,
                           TuningServer, TuningServiceError, WorkloadSpec,
                           probe_key, serve_background)
from repro.service.shardlog import shard_index
from repro.service.wire import space_from_json, space_to_json

WATCHDOG_S = 120


@pytest.fixture(autouse=True)
def _watchdog():
    if not hasattr(signal, "SIGALRM"):
        yield
        return

    def _fire(signum, frame):
        raise TimeoutError(f"tuning-service test exceeded {WATCHDOG_S}s "
                           "(deadlocked pool/session?)")

    old = signal.signal(signal.SIGALRM, _fire)
    signal.alarm(WATCHDOG_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


# ---------------------------------------------------------------------------
# synthetic seeded workload
# ---------------------------------------------------------------------------

class _BD:
    feasible = True


class SeededQuad:
    """Seed-deterministic synthetic benchmark: the value depends only on
    (config, seed) — the PR 7 contract the probe cache builds on.  Call
    counting is lock-guarded (pool workers score concurrently)."""

    accepts_seeds = True

    def __init__(self, shift=0.0):
        self.shift = shift
        self.calls = 0
        self._lock = threading.Lock()

    def evaluate_batch_detailed(self, cfgs, seeds=None):
        with self._lock:
            self.calls += len(cfgs)
        vals = []
        for i, c in enumerate(cfgs):
            s = None if seeds is None else seeds[i]
            rng = np.random.default_rng(0 if s is None else s)
            vals.append((c["x"] - 0.3) ** 2 + (c["y"] - 0.7) ** 2
                        + self.shift + 0.01 * rng.standard_normal())
        return vals, [_BD()] * len(cfgs)


def _space():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0)))


def _server(**kw):
    kw.setdefault("max_workers", 4)
    return TuningServer(
        {"quad": WorkloadSpec("quad", lambda: (_space(), SeededQuad())),
         "quad2": WorkloadSpec("quad2",
                               lambda: (_space(), SeededQuad(shift=1.0)))},
        **kw)


BO_KW = {"cfg": {"n_init": 4, "n_iter": 8, "fit_steps": 15}}


def _backend(server, workload):
    return server.pool.inner.backends[workload]


# ---------------------------------------------------------------------------
# probe cache
# ---------------------------------------------------------------------------

class TestProbeCache:
    def test_unseeded_requests_bypass(self):
        assert probe_key(EvalRequest({"x": 1})) is None
        cache = ProbeCache()
        verdict, res = cache.lookup(None, "w")
        assert (verdict, res) == ("uncached", None)
        assert cache.stats["uncached"] == 1 and cache.hit_rate == 0.0

    def test_key_identity(self):
        a = EvalRequest({"x": 1, "y": 2.0}, "f", "wl", "tag-a", seed=7)
        b = EvalRequest({"y": 2.0, "x": 1}, "f", "wl", "tag-b", seed=7)
        assert probe_key(a) == probe_key(b)     # order/tag-insensitive
        assert probe_key(a) != probe_key(
            EvalRequest({"x": 1, "y": 2.0}, "f", "wl", seed=8))
        assert probe_key(a) != probe_key(
            EvalRequest({"x": 1, "y": 2.0}, "g", "wl", seed=7))
        assert probe_key(a) != probe_key(
            EvalRequest({"x": 1, "y": 2.0}, "f", "other", seed=7))
        # numpy-typed configs key identically to plain ones
        c = EvalRequest({"x": np.int64(1), "y": np.float64(2.0)},
                        "f", "wl", seed=7)
        assert probe_key(c) == probe_key(a)

    def _result(self, req, value, status="ok"):
        return EvalResult(EvalTicket(0, req), value, status=status,
                          feasible=status == "ok")

    def test_completed_hit_and_lru_eviction(self):
        cache = ProbeCache(capacity=2)
        reqs = [EvalRequest({"x": i}, seed=i) for i in range(3)]
        keys = [probe_key(r) for r in reqs]
        for k, r in zip(keys, reqs):
            assert cache.lookup(k, "w")[0] == "miss"
            cache.settle(k, self._result(r, 1.0))
        # capacity 2: key 0 evicted, 1 and 2 live
        assert cache.lookup(keys[0], "w")[0] == "miss"
        assert cache.lookup(keys[1], "w")[0] == "hit"
        assert cache.lookup(keys[2], "w")[0] == "hit"
        assert cache.stats["evictions"] >= 1

    def test_inflight_waiters_and_failed_not_stored(self):
        cache = ProbeCache()
        req = EvalRequest({"x": 1}, seed=3)
        key = probe_key(req)
        assert cache.lookup(key, "owner")[0] == "miss"
        assert cache.lookup(key, "w1")[0] == "wait"
        assert cache.lookup(key, "w2")[0] == "wait"
        waiters = cache.settle(key, self._result(req, 0.0, status="failed"))
        assert waiters == ["w1", "w2"]
        # failed results are delivered but not cached: next lookup re-owns
        assert cache.lookup(key, "owner")[0] == "miss"
        ok = cache.settle(key, self._result(req, 2.5))
        assert ok == []
        verdict, res = cache.lookup(key, "w3")
        assert verdict == "hit" and res.value == 2.5


# ---------------------------------------------------------------------------
# shared pool + ordered views
# ---------------------------------------------------------------------------

class TestSharedPool:
    def test_view_releases_in_submission_order(self):
        """Workers complete out of order (earlier uids sleep longer);
        an ordered view must still release uid 0, 1, 2, ..."""
        import time as _time

        class Slow:
            accepts_seeds = True

            def evaluate_batch_detailed(self, cfgs, seeds=None):
                _time.sleep(0.02 * float(cfgs[0]["d"]))
                return [float(cfgs[0]["d"])], [_BD()]

        pool = SharedEvaluationPool({"wl": Slow()}, max_workers=4)
        view = pool.view(ordered=True)
        n = 6
        # delay decreases with index: last submitted completes first
        tickets = view.submit([
            EvalRequest({"d": n - i, "i": i}, workload="wl", seed=i)
            for i in range(n)])
        got = []
        while len(got) < n:
            got += view.poll(timeout=None)
        assert [r.ticket.uid for r in got] == [t.uid for t in tickets]
        assert [r.request.config["i"] for r in got] == list(range(n))
        pool.close()

    def test_cross_view_inflight_dedup(self):
        """Two views racing the same seeded probe: one backend call,
        both views get the measurement, re-ticketed per view."""
        import time as _time

        class SlowCounting(SeededQuad):
            def evaluate_batch_detailed(self, cfgs, seeds=None):
                _time.sleep(0.05)
                return super().evaluate_batch_detailed(cfgs, seeds)

        backend = SlowCounting()
        pool = SharedEvaluationPool({"wl": backend}, max_workers=4)
        v1, v2 = pool.view(), pool.view()
        req = EvalRequest({"x": 0.2, "y": 0.9}, workload="wl", seed=11)
        (t1,) = v1.submit([req])
        (t2,) = v2.submit([req])
        (r1,) = v1.gather([t1])
        (r2,) = v2.gather([t2])
        assert r1.value == r2.value and r1.ok and r2.ok
        assert r1.ticket.uid == t1.uid and r2.ticket.uid == t2.uid
        assert backend.calls == 1
        assert pool.cache.stats["hits_inflight"] == 1
        pool.close()

    def test_unknown_workload_fails_result_not_exception(self):
        pool = SharedEvaluationPool({"wl": SeededQuad()}, max_workers=2)
        view = pool.view()
        (t,) = view.submit([EvalRequest({"x": 0.1, "y": 0.1},
                                        workload="nope", seed=1)])
        (r,) = view.gather([t])
        assert not r.ok and "no backend for workload" in r.error
        pool.close()


# ---------------------------------------------------------------------------
# session isolation + cross-session sharing (the tentpole contract)
# ---------------------------------------------------------------------------

class TestSessionIsolation:
    def test_shared_probes_bit_exact_disjoint_state(self):
        with _server() as srv:
            s1 = srv.create_session("quad", budget=8, seed=5,
                                    strategy_kwargs=BO_KW)
            s2 = srv.create_session("quad", budget=8, seed=5,
                                    strategy_kwargs=BO_KW)
            t1 = s1.run()
            t2 = s2.run()
            # bit-exact sharing: the cached probe IS the measurement
            assert t1.values == t2.values
            assert t1.configs == t2.configs
            # disjoint strategy state and EvalDB namespaces
            assert s1.strategy is not s2.strategy
            assert s1.db.ns != s2.db.ns
            recs1, recs2 = s1.db.records, s2.db.records
            assert len(recs1) == len(recs2) == 8
            assert {r.ns for r in recs1} == {s1.db.ns}
            assert {r.ns for r in recs2} == {s2.db.ns}
            # the second session re-evaluated nothing
            assert _backend(srv, "quad").calls == 8
            assert srv.pool.cache.stats["hits"] == 8

    def test_server_trace_bit_identical_to_local_run(self):
        """Acceptance: a single server-side session over the shared
        worker pool produces the trace a local ``run_async`` on an
        immediate service produces, same seed — same barrier cadence,
        same seeds, same values, bit for bit."""
        budget, seed = 10, 7
        with _server() as srv:
            sess = srv.create_session("quad", budget=budget, seed=seed,
                                      strategy_kwargs=BO_KW)
            server_trace = sess.run()
        strat = make_strategy("bo", _space(), budget=budget, seed=seed,
                              cfg=BOConfig(**BO_KW["cfg"]))
        local = Controller(ImmediateEvaluationService(SeededQuad()),
                           db=EvalDB(), tag="bo", workload="quad",
                           seed=seed)
        local_trace = local.run_async(strat, budget=budget,
                                      max_in_flight=1, min_ask=1)
        assert server_trace.values == local_trace.values
        assert server_trace.configs == local_trace.configs
        assert server_trace.best_values == local_trace.best_values

    def test_threaded_stress_shared_workloads(self):
        """8 concurrent clients, 2 workloads, 4 clients each sharing a
        seed: every probe is evaluated once per workload, the cache
        serves the rest, and no session's namespace leaks."""
        budget = 6
        kw = {"cfg": {"n_init": 3, "n_iter": 3, "fit_steps": 10}}
        with _server(max_workers=4) as srv:
            sessions, errors = [], []
            lock = threading.Lock()

            def client(workload):
                try:
                    s = srv.create_session(workload, budget=budget,
                                           seed=3, strategy_kwargs=kw)
                    with lock:
                        sessions.append(s)
                    s.run()
                except Exception as e:          # pragma: no cover
                    errors.append(e)

            threads = [threading.Thread(
                target=client, args=("quad" if i % 2 else "quad2",))
                for i in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errors
            assert len(sessions) == 8
            namespaces = {s.db.ns for s in sessions}
            assert len(namespaces) == 8
            for s in sessions:
                assert len(s.db.records) == budget
                assert {r.ns for r in s.db.records} == {s.db.ns}
            # one evaluation per distinct probe per workload
            calls = (_backend(srv, "quad").calls
                     + _backend(srv, "quad2").calls)
            assert calls == 2 * budget
            stats = srv.pool.cache.snapshot()
            assert stats["hits"] == 8 * budget - 2 * budget
            assert stats["hit_rate"] >= 0.4

    def test_sharded_log_roundtrip_on_disk(self, tmp_path):
        root = str(tmp_path / "log")
        with _server(db_root=root, n_shards=3) as srv:
            s1 = srv.create_session("quad", budget=5, seed=1,
                                    strategy_kwargs=BO_KW)
            s2 = srv.create_session("quad2", budget=5, seed=2,
                                    strategy_kwargs=BO_KW)
            s1.run()
            s2.run()
            ns1, ns2 = s1.db.ns, s2.db.ns
        reloaded = ShardedEvalLog(root, n_shards=3)
        assert set(reloaded.namespaces()) == {ns1, ns2}
        assert reloaded.counts() == {ns1: 5, ns2: 5}
        view = reloaded.namespace(ns1)
        assert len(view) == 5
        assert all(r.workload == "quad" for r in view.records)
        cfgs, vals = view.pairs()
        assert len(cfgs) == len(vals) == 5

    def test_closed_session_rejects_everything(self):
        with _server() as srv:
            s = srv.create_session("quad", budget=4, seed=0,
                                   strategy_kwargs=BO_KW)
            sid = s.session_id
            srv.close_session(sid)
            with pytest.raises(SessionClosed):
                s.ask()
            with pytest.raises(KeyError):
                srv.session(sid)


# ---------------------------------------------------------------------------
# sharded log unit behavior
# ---------------------------------------------------------------------------

class TestShardLog:
    def test_stable_shard_routing(self):
        assert shard_index("s0001", 4) == shard_index("s0001", 4)
        log = ShardedEvalLog(None, n_shards=4)
        db = log.namespace("abc")
        assert db.shard is log.shards[shard_index("abc", 4)]
        with pytest.raises(ValueError):
            log.namespace("")

    def test_namespace_filtering(self):
        log = ShardedEvalLog(None, n_shards=1)     # force shard collision
        a, b = log.namespace("a"), log.namespace("b")
        a.append(EvalRecord({"x": 1}, 1.0, 0.0, "t"))
        b.append_batch([EvalRecord({"x": 2}, 2.0, 0.0, "t")])
        assert len(a) == 1 and len(b) == 1 and len(log) == 2
        assert a.records[0].value == 1.0 and a.records[0].ns == "a"
        assert b.records[0].value == 2.0 and b.records[0].ns == "b"


# ---------------------------------------------------------------------------
# EvalDB concurrent writers (advisory file lock)
# ---------------------------------------------------------------------------

class TestEvalDBFileLock:
    def test_two_objects_one_path_no_torn_lines(self, tmp_path):
        """Two EvalDB objects (distinct in-process locks!) hammering one
        path: the flock serializes batches, so every reloaded line
        parses and nothing interleaves."""
        path = str(tmp_path / "shared.jsonl")
        dbs = [EvalDB(path, shared_path=True) for _ in range(2)]
        n, batch = 40, 5

        def writer(db, tag):
            for i in range(n // batch):
                db.append_batch([
                    EvalRecord({"k" * 30: i * batch + j}, float(j), 0.0,
                               tag, "w" * 40)
                    for j in range(batch)])

        threads = [threading.Thread(target=writer, args=(db, f"t{i}"))
                   for i, db in enumerate(dbs)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        lines = [ln for ln in open(path).read().splitlines() if ln.strip()]
        assert len(lines) == 2 * n
        for ln in lines:                 # every line is whole JSON
            json.loads(ln)
        reloaded = EvalDB(path)
        assert len(reloaded) == 2 * n
        assert {r.tag for r in reloaded.records} == {"t0", "t1"}

    def test_shared_path_fails_loudly_without_fcntl(self, tmp_path,
                                                    monkeypatch):
        import repro.core.controller as ctl
        db = EvalDB(str(tmp_path / "x.jsonl"), shared_path=True)
        monkeypatch.setattr(ctl, "fcntl", None)
        with pytest.raises(RuntimeError, match="advisory"):
            db.append(EvalRecord({"a": 1}, 1.0, 0.0))
        # unshared paths keep working (single-writer legacy contract)
        solo = EvalDB(str(tmp_path / "y.jsonl"))
        solo.append(EvalRecord({"a": 1}, 1.0, 0.0))
        assert len(EvalDB(str(tmp_path / "y.jsonl"))) == 1


# ---------------------------------------------------------------------------
# HTTP wire
# ---------------------------------------------------------------------------

class TestWire:
    @pytest.fixture()
    def service(self):
        srv = _server()
        httpd, _ = serve_background(srv)
        host, port = httpd.server_address[:2]
        try:
            yield TuningClient(f"http://{host}:{port}"), srv
        finally:
            httpd.shutdown()
            srv.close()

    def test_lifecycle_ask_tell_best_history_close(self, service):
        client, srv = service
        assert client.health()["ok"]
        assert {w["name"] for w in client.workloads()} == {"quad", "quad2"}
        sess = client.create_session("quad", strategy="random", budget=6,
                                     seed=1)
        assert [k.name for k in sess.space.knobs] == ["x", "y"]
        cfgs = sess.ask(3)
        assert len(cfgs) == 3 and all("x" in c for c in cfgs)
        assert sess.tell(cfgs, [3.0, 1.0, 2.0],
                         variances=[0.1, 0.1, 0.1]) == 3
        cfg, val = sess.best()
        assert val == 1.0 and cfg == cfgs[1]
        recs = sess.history()
        assert len(recs) == 3
        assert all(r["fidelity"] == "client" for r in recs)
        assert sess.history(limit=2)[-1]["value"] == 2.0
        sess.close()
        with pytest.raises(TuningServiceError) as ei:
            sess.ask()
        assert ei.value.status == 404            # closed = gone
        assert client.stats()["sessions_open"] == 0

    def test_server_side_run_and_state(self, service):
        client, srv = service
        sess = client.create_session("quad", budget=8, seed=4,
                                     strategy_kwargs=BO_KW)
        out = sess.run()
        assert out["n_evaluations"] == 8
        assert out["best_value"] == min(out["trace"]["values"])
        assert len(out["trace"]["configs"]) == 8
        state = sess.state()
        assert state["kind"] == "bo" and state["version"] == 1
        # warm restart: a new session resumes from the snapshot
        warm = client.create_session("quad", budget=16, seed=9,
                                     strategy_kwargs=BO_KW, state=state)
        assert len(warm.ask(2)) == 2
        # in-process equivalence: the wire adds serialization, nothing else
        twin = srv.create_session("quad", budget=8, seed=4,
                                  strategy_kwargs=BO_KW)
        assert twin.run().values == out["trace"]["values"]

    def test_error_codes(self, service):
        client, _ = service
        with pytest.raises(TuningServiceError) as ei:
            client.create_session("no-such-workload")
        assert ei.value.status == 404
        with pytest.raises(TuningServiceError) as ei:
            client.create_session("quad", strategy="zzz")
        assert ei.value.status == 404 or ei.value.status == 400
        with pytest.raises(TuningServiceError) as ei:
            client._call("POST", "/v1/sessions", {"workload": "quad",
                                                  "bogus_field": 1})
        assert ei.value.status == 400
        sess = client.create_session("quad", strategy="random", budget=4)
        with pytest.raises(TuningServiceError) as ei:
            sess.best()
        assert ei.value.status == 409            # no observations yet
        with pytest.raises(TuningServiceError) as ei:
            client._call("GET", "/v1/nope")
        assert ei.value.status == 404

    def test_space_codec_roundtrip(self):
        space = Space(
            (Knob("i", "int", 4, lo=1, hi=64, align=2, log_scale=True,
                  dynamic_bound=True, module="m", description="d"),
             Knob("f", "float", 0.5, lo=0.0, hi=1.0,
                  restart_required=False),
             Knob("b", "bool", True, inert=True),
             Knob("c", "categorical", "a", choices=("a", "b", "c"),
                  gated_by=("b", (True,)), configurable=False)),
            (SumLeq(("i", "f"), limit=32.0), Leq(("f", "i")),
             Divides(("i",), target=64),
             ProductLeq(("i", "i"), limit=4096.0)))
        decoded = space_from_json(json.loads(json.dumps(
            space_to_json(space))))
        assert decoded == space


# ---------------------------------------------------------------------------
# satellite: BOStrategy state_dict / load_state
# ---------------------------------------------------------------------------

class TestBOStateDict:
    def _run_one(self, budget=8, seed=3):
        cfg = BOConfig(n_init=4, n_iter=12, fit_steps=15, seed=seed)
        strat = BOStrategy(_space(), cfg)
        ctrl = Controller(ImmediateEvaluationService(SeededQuad()),
                          db=EvalDB(), seed=seed)
        ctrl.run_async(strat, budget=budget, max_in_flight=1, min_ask=1)
        return strat

    def test_roundtrip_restores_trace_params_and_budget(self):
        a = self._run_one()
        sd = json.loads(json.dumps(a.state_dict()))   # wire-safe
        b = BOStrategy(_space(), BOConfig(n_init=4, n_iter=12,
                                          fit_steps=15, seed=3))
        b.load_state(sd)
        assert b.trace.values == a.trace.values
        assert b.trace.configs == a.trace.configs
        assert b._evals_done == a._evals_done
        assert not b.finished
        np.testing.assert_array_equal(
            np.asarray(b._params.log_lengthscale),
            np.asarray(a._params.log_lengthscale))
        assert float(b._params.log_noise_var) == float(
            a._params.log_noise_var)
        # the restored strategy resumes asking within the restored space
        nxt = b.ask(2)
        assert len(nxt) == 2
        for c in nxt:
            assert set(c) == {"x", "y"}

    def test_boundary_state_survives(self):
        space = Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0,
                            dynamic_bound=True),
                       Knob("y", "float", 0.5, lo=0.0, hi=1.0)))
        cfg = BOConfig(n_init=3, n_iter=9, fit_steps=10,
                       boundary_tol=0.45, seed=0)
        strat = BOStrategy(space, cfg)
        ctrl = Controller(ImmediateEvaluationService(SeededQuad()),
                          db=EvalDB(), seed=0)
        ctrl.run_async(strat, budget=9, max_in_flight=1, min_ask=1)
        sd = strat.state_dict()
        fresh = BOStrategy(space, cfg)
        fresh.load_state(sd)
        assert (float(fresh.space.knob("x").lo),
                float(fresh.space.knob("x").hi)) == tuple(sd["bounds"]["x"])
        assert fresh.trace.boundary_events == strat.trace.boundary_events
        assert fresh._space_version == strat._space_version

    def test_load_state_validates(self):
        a = self._run_one(budget=5)
        sd = a.state_dict()
        b = BOStrategy(_space(), BOConfig(n_init=4, n_iter=12))
        with pytest.raises(ValueError, match="version"):
            b.load_state({**sd, "version": 99})
        with pytest.raises(ValueError, match="kernel"):
            b.load_state({**sd, "kernel": "rbf"})
        with pytest.raises(ValueError, match="knobs"):
            b.load_state({**sd, "bounds": {"zz": [0.0, 1.0]}})

    def test_other_strategies_refuse_state(self):
        with _server() as srv:
            with pytest.raises(TypeError, match="load_state"):
                srv.create_session("quad", strategy="random", budget=4,
                                   state={"kind": "random"})


# ---------------------------------------------------------------------------
# satellite: GP-prior racing intervals + variance-widened promotion
# ---------------------------------------------------------------------------

class TestGPPriorRacing:
    def _group(self, values, asked=None):
        return {"stats": RepeatStats.from_values(values),
                "asked": asked or {"x": 0.5}, "prepared": {"x": 0.5},
                "result": None, "measured": len(values), "extras": 0}

    def test_mean_var_pools_toward_prior(self):
        pol = ReplicationPolicy(n_repeats=2, adaptive=True)
        svc = ImmediateEvaluationService(SeededQuad())
        empirical = AdaptiveRacer(pol, svc)
        g = self._group([1.0, 1.2])              # s^2 = 0.02, k = 2
        assert empirical._mean_var(g) == pytest.approx(0.01)
        # prior-aware: nu=1, w=2 -> pooled = (0.02 + 2*v0)/3, /k
        prior = AdaptiveRacer(pol, svc, noise_prior=lambda c: 0.08)
        assert prior._mean_var(g) == pytest.approx(
            ((1 * 0.02 + 2 * 0.08) / 3) / 2)
        # a strategy with no posterior yet falls back to empirical
        lazy = AdaptiveRacer(pol, svc, noise_prior=lambda c: None)
        assert lazy._mean_var(g) == empirical._mean_var(g)

    def test_prior_widens_deceptively_tight_repeats(self):
        """Two repeats that landed close together look settled to the
        empirical interval; the GP noise prior (trained on every config)
        knows the benchmark is noisier than that and keeps racing."""
        pol = ReplicationPolicy(n_repeats=2, adaptive=True, z=2.0)
        svc = ImmediateEvaluationService(SeededQuad())
        g = self._group([1.0, 1.001])
        empirical = AdaptiveRacer(pol, svc)
        prior = AdaptiveRacer(pol, svc, noise_prior=lambda c: 0.5)
        assert prior._mean_var(g) > 10 * empirical._mean_var(g)

    def test_bo_measurement_variance_exposed(self):
        strat = self._fit_bo()
        v = strat.measurement_variance({"x": 0.4, "y": 0.6})
        assert v is not None and v > 0.0
        fresh = BOStrategy(_space(), BOConfig())
        assert fresh.measurement_variance({"x": 0.4, "y": 0.6}) is None

    def _fit_bo(self):
        cfg = BOConfig(n_init=4, n_iter=8, fit_steps=15, seed=1)
        strat = BOStrategy(_space(), cfg)
        ctrl = Controller(ImmediateEvaluationService(SeededQuad()),
                          db=EvalDB(), seed=1)
        ctrl.run_async(strat, budget=6, max_in_flight=1, min_ask=1)
        return strat

    def test_adaptive_run_uses_gp_prior_by_default(self):
        pol = ReplicationPolicy(n_repeats=2, adaptive=True, max_repeats=4)
        strat = BOStrategy(_space(), BOConfig(n_init=3, n_iter=5,
                                              fit_steps=10, seed=2))
        ctrl = Controller(ImmediateEvaluationService(SeededQuad()),
                          db=EvalDB(), seed=2, replication=pol)
        trace = ctrl.run_async(strat, budget=8)
        assert len(trace.values) == 8
        # gp_prior=False keeps the legacy empirical-only racer working
        pol_off = ReplicationPolicy(n_repeats=2, adaptive=True,
                                    max_repeats=4, gp_prior=False)
        strat2 = BOStrategy(_space(), BOConfig(n_init=3, n_iter=5,
                                               fit_steps=10, seed=2))
        ctrl2 = Controller(ImmediateEvaluationService(SeededQuad()),
                           db=EvalDB(), seed=2, replication=pol_off)
        assert len(ctrl2.run_async(strat2, budget=8).values) == 8


class TestVarianceWidenedPromotion:
    class _ListStrategy:
        """Asks a scripted candidate list once; records what it's told."""

        def __init__(self, cands):
            self.cands = list(cands)
            self.told = []
            self.asked = False
            from repro.core.strategy import Trace
            self.trace = Trace()

        @property
        def finished(self):
            return self.asked

        def ask(self, n=None):
            self.asked = True
            return [dict(c) for c in self.cands]

        def tell(self, configs, values, variances=None):
            self.told.append((list(values), list(variances or [])))
            self.trace.extend(configs, values, variances)

        def best(self):
            return self.trace.best

    class _PresetService:
        """Immediate service returning scripted (value, variance) per
        config key; promotion fidelity returns value + 10."""

        def __init__(self, table):
            from repro.core.service import _ServiceBase
            self.table = table
            base = _ServiceBase()
            self._base = base

        def submit(self, requests):
            from repro.core.service import EvalResult
            tickets = self._base._issue(requests)
            for t in tickets:
                v, var = self.table[t.request.config["name"]]
                if t.request.fidelity == "promote":
                    v, var = v + 10.0, 0.0
                self._base._complete(EvalResult(t, v, variance=var))
            return tickets

        def poll(self, timeout=0.0, min_results=1):
            return self._base.poll(timeout, min_results)

        def gather(self, tickets):
            return self._base.gather(tickets)

        def drain(self):
            return self._base.drain()

        def close(self):
            pass

    def _run(self, promote_z):
        # A: best raw mean but huge screen variance; B: slightly worse
        # mean, measured precisely
        table = {"A": (1.0, 0.09), "B": (1.05, 0.0)}
        cands = [{"name": "A"}, {"name": "B"}]
        strat = self._ListStrategy(cands)
        ctrl = Controller(self._PresetService(table), db=EvalDB())
        best_c, best_v, sched = ctrl.run_successive_halving(
            strat, rounds=1, screen=2, promote=1, promote_z=promote_z)
        return strat, sched

    def test_promote_z_zero_ranks_on_raw_mean(self):
        strat, sched = self._run(promote_z=0.0)
        assert sched[0]["promoted_configs"] == [{"name": "A"}]

    def test_promote_z_widens_noisy_screens(self):
        # widened(A) = 1.0 + 2*0.3 = 1.6 > widened(B) = 1.05
        strat, sched = self._run(promote_z=2.0)
        assert sched[0]["promoted_configs"] == [{"name": "B"}]
        # the strategy is told un-widened means, with variances
        values, variances = strat.told[0]
        assert values[0] == 1.0 and variances[0] == 0.09
        assert values[1] == 11.05 and variances[1] == 0.0


# ---------------------------------------------------------------------------
# projected probe keys (ROADMAP service rung (d))
# ---------------------------------------------------------------------------

def _decoy_space():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("decoy", "int", 0, lo=0, hi=8, inert=True),
                  Knob("mode", "categorical", "off",
                       choices=("off", "on")),
                  Knob("depth", "int", 2, lo=1, hi=4,
                       gated_by=("mode", ("on",)))))


class TestProjectedProbeKeys:
    def test_inert_and_gated_off_knobs_dropped(self):
        sp = _decoy_space()
        base = {"x": 0.25, "y": 0.5, "mode": "off", "depth": 3}
        a = EvalRequest({**base, "decoy": 1}, workload="w", seed=7)
        b = EvalRequest({**base, "decoy": 6, "depth": 1},
                        workload="w", seed=7)
        assert probe_key(a) != probe_key(b)          # raw keys differ
        assert probe_key(a, sp) == probe_key(b, sp)  # projected collide
        # gate open: depth is live again and must key
        on3 = EvalRequest({**base, "decoy": 0, "mode": "on", "depth": 3},
                          workload="w", seed=7)
        on4 = EvalRequest({**base, "decoy": 0, "mode": "on", "depth": 4},
                          workload="w", seed=7)
        assert probe_key(on3, sp) != probe_key(on4, sp)
        assert probe_key(on3, sp) != probe_key(a, sp)
        # unseeded probes stay uncacheable, space or not
        assert probe_key(EvalRequest({**base, "decoy": 1},
                                     workload="w"), sp) is None

    def test_pool_cache_hit_across_inert_variants(self):
        """The regression: two sessions probing configs that differ only
        in an inert decoy knob must share one measurement once the
        workload's space is registered."""
        backend = SeededQuad()
        pool = SharedEvaluationPool({"wl": backend}, max_workers=2)
        pool.register_space("wl", _decoy_space())
        v1, v2 = pool.view(), pool.view()
        cfg = {"x": 0.2, "y": 0.9, "mode": "off", "depth": 3}
        (t1,) = v1.submit([EvalRequest({**cfg, "decoy": 1},
                                       workload="wl", seed=11)])
        (r1,) = v1.gather([t1])
        (t2,) = v2.submit([EvalRequest({**cfg, "decoy": 7, "depth": 1},
                                       workload="wl", seed=11)])
        (r2,) = v2.gather([t2])
        assert r1.ok and r2.ok and r1.value == r2.value
        assert backend.calls == 1
        assert pool.cache.stats["hits_completed"] == 1
        pool.close()

    def test_without_space_variants_remeasure(self):
        backend = SeededQuad()
        pool = SharedEvaluationPool({"wl": backend}, max_workers=2)
        v = pool.view()
        cfg = {"x": 0.2, "y": 0.9, "mode": "off", "depth": 3}
        for decoy in (1, 7):
            (t,) = v.submit([EvalRequest({**cfg, "decoy": decoy},
                                         workload="wl", seed=11)])
            v.gather([t])
        assert backend.calls == 2
        pool.close()

    def test_server_registers_space_on_resolve(self):
        with _server() as srv:
            srv.create_session("quad", strategy="random", budget=4)
            assert "quad" in srv.pool.spaces


# ---------------------------------------------------------------------------
# idle-session eviction + snapshot/resume
# ---------------------------------------------------------------------------

class TestSessionEviction:
    def test_no_ttl_never_evicts(self):
        import time as _time
        with _server() as srv:
            srv.create_session("quad", strategy="random", budget=4)
            assert srv.evict_idle(now=_time.time() + 1e9) == []
            assert srv.stats()["sessions_open"] == 1

    def test_idle_eviction_snapshots_and_resumes(self, tmp_path):
        import time as _time
        with _server(db_root=str(tmp_path), session_ttl=60.0) as srv:
            sess = srv.create_session("quad", budget=8, seed=2,
                                      strategy_kwargs=BO_KW)
            sid = sess.session_id
            cfgs = sess.ask(2)
            sess.tell(cfgs, [1.0, 2.0])
            best = sess.best()
            assert srv.evict_idle(now=_time.time() + 3600) == [sid]
            assert sess.closed
            with pytest.raises(KeyError, match=sid):
                srv.session(sid)
            stats = srv.stats()
            assert stats["sessions_evicted"] == 1
            assert stats["sessions_open"] == 0
            assert (tmp_path / "sessions" / f"{sid}.json").exists()
            resumed = srv.create_session("quad", budget=8, seed=2,
                                         strategy_kwargs=BO_KW,
                                         resume=sid)
            assert resumed.session_id != sid
            assert resumed.best() == best
            assert len(resumed.strategy.trace.values) == 2

    def test_entrypoint_sweep_is_lazy(self):
        with _server(session_ttl=60.0) as srv:
            sess = srv.create_session("quad", strategy="random", budget=4)
            sid = sess.session_id
            assert srv.list_sessions()          # fresh: survives the sweep
            sess.last_used -= 3600              # backdate: now idle
            with pytest.raises(KeyError):
                srv.session(sid)                # the lookup itself sweeps
            assert srv.list_sessions() == []

    def test_activity_resets_the_idle_clock(self):
        import time as _time
        with _server(session_ttl=60.0) as srv:
            sess = srv.create_session("quad", strategy="random", budget=8)
            sess.last_used -= 50                # idle, but under the ttl
            cfgs = sess.ask(1)                  # activity touches
            sess.tell(cfgs, [1.0])
            assert _time.time() - sess.last_used < 5
            assert srv.evict_idle() == []

    def test_resume_guards(self, tmp_path):
        import time as _time
        with _server(db_root=str(tmp_path), session_ttl=60.0) as srv:
            sess = srv.create_session("quad", budget=8, seed=2,
                                      strategy_kwargs=BO_KW)
            sid = sess.session_id
            sess.tell([{"x": 0.1, "y": 0.2}], [1.0])
            srv.evict_idle(now=_time.time() + 3600)
            with pytest.raises(KeyError, match="no session snapshot"):
                srv.create_session("quad", resume="s9999")
            with pytest.raises(ValueError, match="not both"):
                srv.create_session("quad", strategy_kwargs=BO_KW,
                                   resume=sid, state={"version": 1})
            with pytest.raises(ValueError, match="belongs to workload"):
                srv.create_session("quad2", strategy_kwargs=BO_KW,
                                   resume=sid)

    def test_resume_from_disk_across_restarts(self, tmp_path):
        import time as _time
        with _server(db_root=str(tmp_path), session_ttl=60.0) as srv:
            sess = srv.create_session("quad", budget=8, seed=2,
                                      strategy_kwargs=BO_KW)
            sid = sess.session_id
            sess.tell([{"x": 0.1, "y": 0.2}], [3.5])
            srv.evict_idle(now=_time.time() + 3600)
        # a brand-new daemon over the same log root: memory snapshots
        # are gone, the file one is found
        with _server(db_root=str(tmp_path)) as srv2:
            resumed = srv2.create_session("quad", budget=8, seed=2,
                                          strategy_kwargs=BO_KW,
                                          resume=sid)
            assert resumed.best() == ({"x": 0.1, "y": 0.2}, 3.5)


# ---------------------------------------------------------------------------
# transfer_from: warm starts mined from the daemon's own log
# ---------------------------------------------------------------------------

class TestTransferFrom:
    def test_mines_sibling_workload_logs(self):
        from repro.transfer import TransferBOStrategy
        with _server() as srv:
            donor = srv.create_session("quad", budget=6, seed=3,
                                       strategy_kwargs=BO_KW)
            donor.run()
            sess = srv.create_session("quad2", strategy="transfer_bo",
                                      budget=6, seed=3,
                                      strategy_kwargs=BO_KW,
                                      transfer_from=True)
            strat = sess.strategy
            assert isinstance(strat, TransferBOStrategy)
            assert strat._prior is not None       # quad's 6 rows fed it
            trace = sess.run()
            assert len(trace.values) == 6

    def test_own_workload_always_excluded(self):
        with _server() as srv:
            donor = srv.create_session("quad", budget=6, seed=3,
                                       strategy_kwargs=BO_KW)
            donor.run()
            sess = srv.create_session("quad", strategy="transfer_bo",
                                      budget=6, seed=4,
                                      strategy_kwargs=BO_KW,
                                      transfer_from=True)
            assert sess.strategy._prior is None   # only donor was itself

    def test_empty_log_degrades_to_plain_bo(self):
        with _server() as srv:
            sess = srv.create_session("quad", strategy="transfer_bo",
                                      budget=6, seed=3,
                                      strategy_kwargs=BO_KW,
                                      transfer_from=True)
            assert sess.strategy._prior is None
            assert len(sess.run().values) == 6

    def test_unknown_spec_field_rejected(self):
        with _server() as srv:
            with pytest.raises(ValueError, match="unknown fields"):
                srv.create_session("quad", strategy="transfer_bo",
                                   strategy_kwargs=BO_KW,
                                   transfer_from={"nope": 1})

    def test_workload_narrowing(self):
        with _server() as srv:
            for wl in ("quad", "quad2"):
                srv.create_session(wl, budget=6, seed=3,
                                   strategy_kwargs=BO_KW).run()
            sess = srv.create_session(
                "quad", strategy="transfer_bo", budget=6, seed=4,
                strategy_kwargs=BO_KW,
                transfer_from={"workloads": ["quad"]})
            assert sess.strategy._prior is None   # narrowed to self only
            sess2 = srv.create_session(
                "quad", strategy="transfer_bo", budget=6, seed=4,
                strategy_kwargs=BO_KW,
                transfer_from={"workloads": ["quad2"]})
            assert sess2.strategy._prior is not None

    def test_wire_transfer_and_resume(self):
        import time as _time
        srv = _server(session_ttl=600.0)
        httpd, _ = serve_background(srv)
        host, port = httpd.server_address[:2]
        client = TuningClient(f"http://{host}:{port}")
        try:
            client.create_session("quad", budget=6, seed=3,
                                  strategy_kwargs=BO_KW).run()
            sess = client.create_session("quad2", strategy="transfer_bo",
                                         budget=6, seed=3,
                                         strategy_kwargs=BO_KW,
                                         transfer_from=True)
            out = sess.run()
            assert out["n_evaluations"] == 6
            sid = sess.session_id
            srv.evict_idle(now=_time.time() + 3600)
            with pytest.raises(TuningServiceError) as ei:
                sess.best()
            assert ei.value.status == 404        # evicted = gone
            resumed = client.create_session("quad2",
                                            strategy="transfer_bo",
                                            budget=6, seed=3,
                                            strategy_kwargs=BO_KW,
                                            resume=sid)
            _, val = resumed.best()
            assert val == out["best_value"]
            with pytest.raises(TuningServiceError) as ei:
                client.create_session("quad", strategy="transfer_bo",
                                      strategy_kwargs=BO_KW,
                                      transfer_from={"nope": 1})
            assert ei.value.status == 400
        finally:
            httpd.shutdown()
            srv.close()


# ---------------------------------------------------------------------------
# crash-safe sessions: journal manifests + same-sid resume (PR 10)
# ---------------------------------------------------------------------------

class TestJournalResume:
    def test_daemon_killed_mid_run_resumes_with_zero_lost_tells(
            self, tmp_path):
        # no evict_idle, no snapshot: the daemon just dies.  Every tell
        # was journaled before the strategy ack'd, so a fresh daemon
        # rebuilds the SAME session id from manifest + journal replay.
        with _server(db_root=str(tmp_path)) as srv:
            sess = srv.create_session("quad", budget=8, seed=2,
                                      strategy_kwargs=BO_KW)
            sid = sess.session_id
            cfgs = sess.ask(3)
            sess.tell(cfgs, [4.0, 2.0, 3.0])
        with _server(db_root=str(tmp_path)) as srv2:
            resumed = srv2.create_session("quad", budget=8, seed=2,
                                          strategy_kwargs=BO_KW,
                                          resume=sid)
            assert resumed.session_id == sid        # same namespace
            assert len(resumed.strategy.trace.values) == 3
            assert resumed.best()[1] == 2.0
            # and the session keeps appending to the same journal
            more = resumed.ask(1)
            resumed.tell(more, [1.5])
            assert len(srv2.log.namespace(sid).records) == 4
            assert resumed.best()[1] == 1.5

    def test_snapshot_still_preferred_over_journal(self, tmp_path):
        import time as _time
        # an evicted session has a snapshot; resume must keep using it
        # (new sid) rather than the crash path (same sid)
        with _server(db_root=str(tmp_path), session_ttl=60.0) as srv:
            sess = srv.create_session("quad", budget=8, seed=2,
                                      strategy_kwargs=BO_KW)
            sid = sess.session_id
            sess.tell([{"x": 0.1, "y": 0.2}], [1.0])
            srv.evict_idle(now=_time.time() + 3600)
            resumed = srv.create_session("quad", budget=8, seed=2,
                                         strategy_kwargs=BO_KW, resume=sid)
            assert resumed.session_id != sid

    def test_journal_resume_guards(self, tmp_path):
        with _server(db_root=str(tmp_path)) as srv:
            sess = srv.create_session("quad", budget=8, seed=2,
                                      strategy_kwargs=BO_KW)
            sid = sess.session_id
            sess.tell([{"x": 0.1, "y": 0.2}], [1.0])
            # still open on this daemon: refuse a second driver
            with pytest.raises(ValueError, match="still open"):
                srv.create_session("quad", resume=sid)
        with _server(db_root=str(tmp_path)) as srv2:
            # wrong workload: the manifest knows whose journal this is
            with pytest.raises(ValueError, match="belongs to workload"):
                srv2.create_session("quad2", resume=sid)
            # no snapshot AND no manifest: same KeyError as before
            with pytest.raises(KeyError, match="no session snapshot"):
                srv2.create_session("quad", resume="s9999")

    def test_restarted_daemon_never_reuses_session_ids(self, tmp_path):
        with _server(db_root=str(tmp_path)) as srv:
            s1 = srv.create_session("quad", strategy="random", budget=4)
            s1.tell([{"x": 0.1, "y": 0.2}], [1.0])
            old = s1.session_id
        with _server(db_root=str(tmp_path)) as srv2:
            s2 = srv2.create_session("quad", strategy="random", budget=4)
            assert s2.session_id != old
            assert int(s2.session_id[1:]) > int(old[1:])

    def test_run_after_journal_resume_continues_budget(self, tmp_path):
        with _server(db_root=str(tmp_path)) as srv:
            sess = srv.create_session("quad", strategy="random", budget=6,
                                      seed=3)
            sid = sess.session_id
            cfgs = sess.ask(2)
            sess.tell(cfgs, [2.0, 3.0])
        with _server(db_root=str(tmp_path)) as srv2:
            resumed = srv2.create_session("quad", strategy="random",
                                          budget=6, seed=3, resume=sid)
            # the 2 replayed tells count: spend only the remaining 4
            trace = resumed.run(budget=4)
            assert len(resumed.strategy.trace.values) == 6
            assert min(trace.values) <= 3.0


# ---------------------------------------------------------------------------
# client transport retries (PR 10)
# ---------------------------------------------------------------------------

class _FlakyTransport:
    """Counts urlopen calls; fails the first ``fail`` with the given
    exception, then returns a canned JSON body."""

    def __init__(self, fail, exc, body=b'{"ok": true}'):
        self.fail = fail
        self.exc = exc
        self.body = body
        self.calls = 0

    def __call__(self, req, timeout=None):
        self.calls += 1
        if self.calls <= self.fail:
            raise self.exc
        import io

        class _Resp(io.BytesIO):
            def __enter__(self):
                return self

            def __exit__(self, *a):
                pass

        return _Resp(self.body)


class TestClientRetries:
    def test_idempotent_table(self):
        from repro.service.client import _idempotent
        assert _idempotent("GET", "/v1/health")
        assert _idempotent("GET", "/v1/sessions/s0001/state")
        assert _idempotent("POST", "/v1/sessions/s0001/ask")
        assert not _idempotent("POST", "/v1/sessions/s0001/tell")
        assert not _idempotent("POST", "/v1/sessions")
        assert not _idempotent("POST", "/v1/sessions/s0001/run")
        assert not _idempotent("POST", "/v1/sessions/s0001/close")

    def test_get_retries_through_transport_flakes(self, monkeypatch):
        import urllib.request
        flaky = _FlakyTransport(2, ConnectionResetError("reset by peer"))
        monkeypatch.setattr(urllib.request, "urlopen", flaky)
        c = TuningClient("http://127.0.0.1:1", retries=3,
                         retry_backoff_s=0.0)
        assert c.health() == {"ok": True}
        assert flaky.calls == 3

    def test_get_exhausts_with_status_zero(self, monkeypatch):
        import urllib.request
        flaky = _FlakyTransport(99, TimeoutError("timed out"))
        monkeypatch.setattr(urllib.request, "urlopen", flaky)
        c = TuningClient("http://127.0.0.1:1", retries=2,
                         retry_backoff_s=0.0)
        with pytest.raises(TuningServiceError) as ei:
            c.health()
        assert ei.value.status == 0
        assert flaky.calls == 3                  # 1 + retries

    def test_tell_never_resent_on_transport_failure(self, monkeypatch):
        import urllib.request
        flaky = _FlakyTransport(99, ConnectionRefusedError("refused"))
        monkeypatch.setattr(urllib.request, "urlopen", flaky)
        c = TuningClient("http://127.0.0.1:1", retries=5,
                         retry_backoff_s=0.0)
        with pytest.raises(TuningServiceError) as ei:
            c._call("POST", "/v1/sessions/s0001/tell",
                    {"configs": [], "values": []})
        assert ei.value.status == 0
        assert "may or may not" in ei.value.message
        assert flaky.calls == 1                  # exactly one attempt
        with pytest.raises(TuningServiceError) as ei:
            c.create_session("quad")
        assert ei.value.status == 0 and flaky.calls == 2

    def test_server_errors_never_retried(self, monkeypatch):
        import urllib.error
        import urllib.request
        calls = [0]

        def boom(req, timeout=None):
            calls[0] += 1
            raise urllib.error.HTTPError(
                req.full_url, 404, "nope", {}, None)

        monkeypatch.setattr(urllib.request, "urlopen", boom)
        c = TuningClient("http://127.0.0.1:1", retries=5,
                         retry_backoff_s=0.0)
        with pytest.raises(TuningServiceError) as ei:
            c.health()
        assert ei.value.status == 404 and calls[0] == 1

    def test_retry_enabled_client_over_a_real_wire(self):
        # a retry-enabled client against a live daemon behaves exactly
        # like the plain one on the happy path (no spurious resends)
        srv = _server()
        httpd, _ = serve_background(srv)
        try:
            url = f"http://127.0.0.1:{httpd.server_port}"
            c = TuningClient(url, retries=2, retry_backoff_s=0.05)
            assert c.health()["ok"] is True
            with c.create_session("quad", strategy="random",
                                  budget=4, seed=1) as sess:
                cfgs = sess.ask(2)
                assert sess.tell(cfgs, [1.0, 2.0]) == 2
                assert sess.best()[1] == 1.0
        finally:
            httpd.shutdown()
            srv.close()


# ---------------------------------------------------------------------------
# breaker + watchdog stats through the daemon (PR 10)
# ---------------------------------------------------------------------------

class TestPoolResilienceStats:
    def test_breaker_sheds_load_and_surfaces_in_stats(self):
        clk = [0.0]
        dead_calls = [0]

        def dead_backend(cfg):
            dead_calls[0] += 1
            raise TimeoutError("benchmark timed out")

        pool = SharedEvaluationPool({"dead": dead_backend}, max_workers=2,
                                    breaker_threshold=3, breaker_reset_s=5.0,
                                    breaker_clock=lambda: clk[0])
        with pool:
            view = pool.view()
            # distinct configs so the probe cache never answers for us
            reqs = [EvalRequest({"x": float(i)}, workload="dead", seed=i)
                    for i in range(8)]
            # serial submits: let the breaker see each outcome
            results = []
            for r in reqs:
                results += view.gather(view.submit([r]))
            assert all(not r.ok for r in results)
            stats = pool.stats()
            assert stats["breakers"]["dead"] == "open"
            assert stats["shed"] == 8 - dead_calls[0] > 0
            shed = [r for r in results if "circuit breaker open" in r.error]
            assert len(shed) == stats["shed"]
            # recovery: clock past reset -> half-open trial; a healed
            # backend closes the breaker again
            pool.inner.backends["dead"] = lambda cfg: 1.0
            clk[0] += 10.0
            assert pool.stats()["breakers"]["dead"] == "half_open"
            (ok,) = view.gather(view.submit(
                [EvalRequest({"x": 99.0}, workload="dead", seed=99)]))
            assert ok.ok
            assert pool.stats()["breakers"]["dead"] == "closed"

    def test_permanent_failures_never_trip_the_breaker(self):
        def picky(cfg):
            raise ValueError("config infeasible")

        pool = SharedEvaluationPool({"picky": picky}, max_workers=2,
                                    breaker_threshold=2)
        with pool:
            view = pool.view()
            for i in range(6):
                (r,) = view.gather(view.submit(
                    [EvalRequest({"x": float(i)}, workload="picky",
                                 seed=i)]))
                assert not r.ok
            stats = pool.stats()
            assert stats["breakers"]["picky"] == "closed"
            assert stats["shed"] == 0

    def test_server_stats_surface_pool_resilience(self):
        with _server() as srv:
            pool_stats = srv.stats()["pool"]
            assert pool_stats["timed_out"] == 0
            assert pool_stats["shed"] == 0
            assert pool_stats["breakers"] == {}
